//! Stream combinators, written in the paper's style: recursion is
//! forwarded through the suspension monad (`eval.map(tail, ...)`), never
//! performed eagerly, so the same code is demand-driven under `Lazy` and
//! pipeline-parallel under `Future`.

use super::{Elem, Stream};
use crate::susp::{Eval, Susp};

impl<T: Elem, E: Eval> Stream<T, E> {
    /// The paper's §3 example:
    ///
    /// ```text
    /// rest match {
    ///   case head#::tail => head#::tail.map(_ filter p)
    ///   case Empty       => Empty
    /// }
    /// ```
    ///
    /// The scan for the next matching head forces tails (as in the
    /// paper); the recursion after a match is forwarded through the
    /// monad.
    pub fn filter<P>(&self, p: P) -> Stream<T, E>
    where
        P: Fn(&T) -> bool + Send + Sync + Clone + 'static,
    {
        let mut rest = self.clone();
        loop {
            match rest.uncons() {
                None => return Stream::Empty,
                Some((head, tail, eval)) => {
                    if p(head) {
                        let p2 = p.clone();
                        let filtered = eval.map(tail, move |s: Stream<T, E>| s.filter(p2));
                        return Stream::cons_cell(eval.clone(), head.clone(), filtered);
                    }
                    let next = tail.force().clone();
                    rest = next;
                }
            }
        }
    }

    /// Map every element (named `map_elems` because `map` on the cell is
    /// the monadic transform).
    pub fn map_elems<U, F>(&self, f: F) -> Stream<U, E>
    where
        U: Elem,
        F: Fn(&T) -> U + Send + Sync + Clone + 'static,
    {
        match self.uncons() {
            None => Stream::Empty,
            Some((head, tail, eval)) => {
                let f2 = f.clone();
                let mapped = eval.map(tail, move |s: Stream<T, E>| s.map_elems(f2));
                Stream::cons_cell(eval.clone(), f(head), mapped)
            }
        }
    }

    /// First `n` elements, suspension-preserving.
    pub fn take(&self, n: usize) -> Stream<T, E> {
        if n == 0 {
            return Stream::Empty;
        }
        match self.uncons() {
            None => Stream::Empty,
            Some((head, tail, eval)) => {
                let taken = eval.map(tail, move |s: Stream<T, E>| s.take(n - 1));
                Stream::cons_cell(eval.clone(), head.clone(), taken)
            }
        }
    }

    /// Drop the first `n` elements (forces them, like Scala's `drop`).
    pub fn dropped(&self, n: usize) -> Stream<T, E> {
        let mut rest = self.clone();
        for _ in 0..n {
            match rest.tail() {
                None => return Stream::Empty,
                Some(t) => {
                    let next = t.clone();
                    rest = next;
                }
            }
        }
        rest
    }

    /// Concatenation, suspension-preserving in the left spine.
    pub fn append(&self, other: Stream<T, E>) -> Stream<T, E> {
        match self.uncons() {
            None => other,
            Some((head, tail, eval)) => {
                let appended =
                    eval.map(tail, move |s: Stream<T, E>| s.append(other));
                Stream::cons_cell(eval.clone(), head.clone(), appended)
            }
        }
    }

    /// Pairwise zip with another stream; stops at the shorter.
    pub fn zip_with<U, V, F>(&self, other: &Stream<U, E>, f: F) -> Stream<V, E>
    where
        U: Elem,
        V: Elem,
        F: Fn(&T, &U) -> V + Send + Sync + Clone + 'static,
    {
        match (self.uncons(), other.uncons()) {
            (Some((h1, t1, eval)), Some((h2, t2, _))) => {
                let head = f(&h1.clone(), h2);
                let t2 = t2.clone();
                let f2 = f.clone();
                let zipped = eval.map(t1, move |s1: Stream<T, E>| {
                    let s2 = t2.force().clone();
                    s1.zip_with(&s2, f2)
                });
                Stream::cons_cell(eval.clone(), head, zipped)
            }
            _ => Stream::Empty,
        }
    }

    // -----------------------------------------------------------------
    // terminal (forcing) consumers
    // -----------------------------------------------------------------

    /// Walk the whole stream, forcing every tail — the paper's `.force`
    /// ("wait for the computation to complete"). Returns the length.
    ///
    /// Every forcing consumer here is also a cooperative-cancellation
    /// safe point: between elements it calls
    /// [`cancel::checkpoint`](crate::susp::cancel::checkpoint), so a
    /// coordinator job whose deadline reaper tripped the ambient token
    /// stops traversing (and forcing further suspensions) at the next
    /// element boundary. Outside a cancel scope the check is a
    /// thread-local read — a no-op for plain library use.
    pub fn force_all(&self) -> usize {
        let mut n = 0;
        let mut cur = self.clone();
        while let Some(t) = cur.tail() {
            crate::susp::cancel::checkpoint();
            n += 1;
            let next = t.clone();
            cur = next;
        }
        n
    }

    /// Collect into a `Vec` (forces everything).
    pub fn to_vec(&self) -> Vec<T> {
        let mut out = Vec::new();
        let mut cur = self.clone();
        while let Some((head, _, _)) = cur.uncons() {
            crate::susp::cancel::checkpoint();
            out.push(head.clone());
            let next = cur.tail().expect("non-empty").clone();
            cur = next;
        }
        out
    }

    /// Left fold (forces everything).
    pub fn fold<Acc, F>(&self, init: Acc, mut f: F) -> Acc
    where
        F: FnMut(Acc, &T) -> Acc,
    {
        let mut acc = init;
        let mut cur = self.clone();
        while let Some((head, _, _)) = cur.uncons() {
            crate::susp::cancel::checkpoint();
            acc = f(acc, head);
            let next = cur.tail().expect("non-empty").clone();
            cur = next;
        }
        acc
    }

    /// Number of elements (forces everything).
    pub fn len(&self) -> usize {
        self.fold(0, |n, _| n + 1)
    }

    /// Last element (forces everything).
    pub fn last(&self) -> Option<T> {
        self.fold(None, |_, x| Some(x.clone()))
    }

    /// Forcing iterator over elements.
    pub fn iter(&self) -> StreamIter<T, E> {
        StreamIter { cur: self.clone() }
    }

    /// Index access (forces a prefix).
    pub fn get(&self, idx: usize) -> Option<T> {
        self.dropped(idx).head().cloned()
    }
}

/// Iterator that forces the stream as it advances.
pub struct StreamIter<T: Elem, E: Eval> {
    cur: Stream<T, E>,
}

impl<T: Elem, E: Eval> Iterator for StreamIter<T, E> {
    type Item = T;

    fn next(&mut self) -> Option<T> {
        crate::susp::cancel::checkpoint();
        let head = self.cur.head().cloned()?;
        let next = self.cur.tail().expect("non-empty").clone();
        self.cur = next;
        Some(head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Executor;
    use crate::susp::{FutureEval, LazyEval, StrictEval};

    fn lazy_range(lo: u32, hi: u32) -> Stream<u32, LazyEval> {
        Stream::range(LazyEval, lo, hi)
    }

    #[test]
    fn filter_keeps_matching() {
        let evens = lazy_range(0, 10).filter(|x| x % 2 == 0);
        assert_eq!(evens.to_vec(), vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn filter_empty_result() {
        let none = lazy_range(0, 10).filter(|x| *x > 100);
        assert!(none.is_empty());
    }

    #[test]
    fn filter_is_lazy_past_first_match() {
        // Only the scan up to the first match may force; the rest stays
        // suspended under Lazy.
        let s = lazy_range(0, 1000).filter(|x| *x >= 5);
        assert_eq!(*s.head().unwrap(), 5);
        assert!(!s.tail_defined());
    }

    #[test]
    fn map_elems_applies() {
        let sq = lazy_range(1, 5).map_elems(|x| x * x);
        assert_eq!(sq.to_vec(), vec![1, 4, 9, 16]);
    }

    #[test]
    fn take_limits_and_preserves_laziness() {
        let t = lazy_range(0, 1_000_000).take(4);
        assert_eq!(t.to_vec(), vec![0, 1, 2, 3]);
        let t = lazy_range(0, 3).take(10);
        assert_eq!(t.to_vec(), vec![0, 1, 2]);
        assert!(lazy_range(0, 5).take(0).is_empty());
    }

    #[test]
    fn dropped_skips() {
        assert_eq!(lazy_range(0, 6).dropped(3).to_vec(), vec![3, 4, 5]);
        assert!(lazy_range(0, 3).dropped(5).is_empty());
    }

    #[test]
    fn append_concatenates() {
        let a = lazy_range(0, 3);
        let b = lazy_range(10, 13);
        assert_eq!(a.append(b).to_vec(), vec![0, 1, 2, 10, 11, 12]);
        let e: Stream<u32, LazyEval> = Stream::Empty;
        assert_eq!(e.append(lazy_range(5, 7)).to_vec(), vec![5, 6]);
    }

    #[test]
    fn zip_with_stops_at_shorter() {
        let a = lazy_range(0, 5);
        let b = lazy_range(0, 3).map_elems(|x| x * 10);
        let z = a.zip_with(&b, |x, y| x + y);
        assert_eq!(z.to_vec(), vec![0, 11, 22]);
    }

    #[test]
    fn fold_len_last_get() {
        let s = lazy_range(1, 6);
        assert_eq!(s.fold(0u32, |a, b| a + b), 15);
        assert_eq!(s.len(), 5);
        assert_eq!(s.last(), Some(5));
        assert_eq!(s.get(2), Some(3));
        assert_eq!(s.get(9), None);
    }

    #[test]
    fn iter_yields_all() {
        let v: Vec<u32> = lazy_range(0, 5).iter().collect();
        assert_eq!(v, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn force_all_counts() {
        assert_eq!(lazy_range(0, 17).force_all(), 17);
        let e: Stream<u32, LazyEval> = Stream::Empty;
        assert_eq!(e.force_all(), 0);
    }

    #[test]
    fn combinators_agree_across_strategies() {
        // The paper's core claim: swapping the monad does not change
        // results. Cross-check a composite pipeline on all strategies.
        fn pipeline<E: Eval>(eval: E) -> Vec<u32> {
            Stream::range(eval, 1, 60)
                .filter(|x| x % 3 != 0)
                .map_elems(|x| x * 2)
                .take(10)
                .to_vec()
        }
        let expected = pipeline(LazyEval);
        assert_eq!(pipeline(StrictEval), expected);
        let ex = Executor::new(3);
        assert_eq!(pipeline(FutureEval::new(ex)), expected);
        let ex1 = Executor::new(1);
        assert_eq!(pipeline(FutureEval::new(ex1)), expected);
    }

    #[test]
    fn deep_filter_chain_under_future() {
        // Stacked filters mimic the sieve's pipeline shape.
        let ex = Executor::new(2);
        let mut s = Stream::range(FutureEval::new(ex), 2, 500);
        for d in 2..20u32 {
            s = s.filter(move |x| *x == d || x % d != 0);
        }
        let got = s.to_vec();
        assert!(got.contains(&2));
        assert!(got.contains(&499)); // 499 is prime
        assert!(!got.contains(&38));
    }
}

//! Second tier of stream combinators: scans, bounded traversals, and
//! stream fusion helpers. Same discipline as `ops.rs` — recursion is
//! forwarded through the suspension monad so each combinator is
//! pipeline-parallel under `Future`.

use super::{Elem, Stream};
use crate::susp::{Eval, Susp};

impl<T: Elem, E: Eval> Stream<T, E> {
    /// Longest prefix satisfying `p` (suspension-preserving).
    pub fn take_while<P>(&self, p: P) -> Stream<T, E>
    where
        P: Fn(&T) -> bool + Send + Sync + Clone + 'static,
    {
        match self.uncons() {
            None => Stream::Empty,
            Some((head, tail, eval)) => {
                if !p(head) {
                    return Stream::Empty;
                }
                let p2 = p.clone();
                let rest = eval.map(tail, move |s: Stream<T, E>| s.take_while(p2));
                Stream::cons_cell(eval.clone(), head.clone(), rest)
            }
        }
    }

    /// Drop the longest prefix satisfying `p` (forces the prefix, like
    /// the paper's filter scan).
    pub fn drop_while<P>(&self, p: P) -> Stream<T, E>
    where
        P: Fn(&T) -> bool + Send + Sync + Clone + 'static,
    {
        let mut cur = self.clone();
        loop {
            match cur.uncons() {
                None => return Stream::Empty,
                Some((head, _, _)) => {
                    if !p(head) {
                        return cur;
                    }
                    let next = cur.tail().expect("non-empty").clone();
                    cur = next;
                }
            }
        }
    }

    /// Running left scan: emits `f(acc, x)` for every element, starting
    /// from `init` (the first emitted element is `f(init, x0)`).
    pub fn scan<A, F>(&self, init: A, f: F) -> Stream<A, E>
    where
        A: Elem,
        F: Fn(&A, &T) -> A + Send + Sync + Clone + 'static,
    {
        match self.uncons() {
            None => Stream::Empty,
            Some((head, tail, eval)) => {
                let acc = f(&init, head);
                let acc2 = acc.clone();
                let f2 = f.clone();
                let rest = eval.map(tail, move |s: Stream<T, E>| s.scan(acc2, f2));
                Stream::cons_cell(eval.clone(), acc, rest)
            }
        }
    }

    /// Map each element to a stream and concatenate (`flatMap`).
    pub fn flat_map_elems<U, F>(&self, f: F) -> Stream<U, E>
    where
        U: Elem,
        F: Fn(&T) -> Stream<U, E> + Send + Sync + Clone + 'static,
    {
        match self.uncons() {
            None => Stream::Empty,
            Some((head, tail, _eval)) => {
                let produced = f(head);
                let f2 = f.clone();
                let tail = tail.clone();
                // Append the suspended flat-mapped rest behind the
                // produced prefix.
                let rest_stream = RestHolder { tail, f: f2, _u: std::marker::PhantomData };
                rest_stream.append_behind(produced)
            }
        }
    }

    /// Alternate elements of two streams, starting with `self`.
    pub fn interleave(&self, other: &Stream<T, E>) -> Stream<T, E> {
        match self.uncons() {
            None => other.clone(),
            Some((head, tail, eval)) => {
                let other = other.clone();
                let interleaved = eval.map(tail, move |s: Stream<T, E>| other.interleave(&s));
                Stream::cons_cell(eval.clone(), head.clone(), interleaved)
            }
        }
    }

    /// Drop consecutive duplicates (`uniq`-style; full dedup would need
    /// unbounded state).
    pub fn dedup_consecutive(&self) -> Stream<T, E>
    where
        T: PartialEq,
    {
        match self.uncons() {
            None => Stream::Empty,
            Some((head, tail, eval)) => {
                let h = head.clone();
                let h2 = h.clone();
                let rest = eval.map(tail, move |s: Stream<T, E>| {
                    s.drop_while(move |x| *x == h2).dedup_consecutive()
                });
                Stream::cons_cell(eval.clone(), h, rest)
            }
        }
    }

    /// Check whether any forced element satisfies `p` (short-circuits).
    pub fn exists<P: Fn(&T) -> bool>(&self, p: P) -> bool {
        let mut cur = self.clone();
        while let Some((head, _, _)) = cur.uncons() {
            if p(head) {
                return true;
            }
            let next = cur.tail().expect("non-empty").clone();
            cur = next;
        }
        false
    }

    /// Merge two streams already sorted under `cmp` (ascending) into one
    /// sorted stream — the generic skeleton of the paper's `plus`
    /// (without coefficient combination).
    pub fn merge_sorted<F>(&self, other: &Stream<T, E>, cmp: F) -> Stream<T, E>
    where
        F: Fn(&T, &T) -> std::cmp::Ordering + Send + Sync + Clone + 'static,
    {
        match (self.uncons(), other.uncons()) {
            (None, _) => other.clone(),
            (_, None) => self.clone(),
            (Some((a, tail_a, eval)), Some((b, _tail_b, _))) => {
                if cmp(a, b) != std::cmp::Ordering::Greater {
                    let other = other.clone();
                    let cmp2 = cmp.clone();
                    let rest =
                        eval.map(tail_a, move |s: Stream<T, E>| s.merge_sorted(&other, cmp2));
                    Stream::cons_cell(eval.clone(), a.clone(), rest)
                } else {
                    other.merge_sorted(self, cmp)
                }
            }
        }
    }
}

/// Helper carrying the suspended "rest" of a flat_map.
struct RestHolder<T: Elem, U: Elem, E: Eval, F> {
    tail: E::Cell<Stream<T, E>>,
    f: F,
    _u: std::marker::PhantomData<U>,
}

impl<T, U, E, F> RestHolder<T, U, E, F>
where
    T: Elem,
    U: Elem,
    E: Eval,
    F: Fn(&T) -> Stream<U, E> + Send + Sync + Clone + 'static,
{
    /// `produced.append(suspended flat_map of tail)` without forcing the
    /// tail now.
    fn append_behind(self, produced: Stream<U, E>) -> Stream<U, E> {
        let RestHolder { tail, f, _u } = self;
        match produced.uncons() {
            None => {
                // Nothing produced here: move on to the tail (forces one
                // step, as any flatMap over an empty prefix must).
                let next = tail.force().clone();
                next.flat_map_elems(f)
            }
            Some((head, ptail, peval)) => {
                let ptail = ptail.clone();
                let rest = peval.map(&ptail, move |p: Stream<U, E>| {
                    let holder = RestHolder { tail, f, _u: std::marker::PhantomData };
                    holder.append_behind(p)
                });
                Stream::cons_cell(peval.clone(), head.clone(), rest)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Executor;
    use crate::susp::{FutureEval, LazyEval};

    fn r(lo: u32, hi: u32) -> Stream<u32, LazyEval> {
        Stream::range(LazyEval, lo, hi)
    }

    #[test]
    fn take_while_stops_at_first_failure() {
        assert_eq!(r(0, 100).take_while(|x| *x < 4).to_vec(), vec![0, 1, 2, 3]);
        assert!(r(5, 10).take_while(|x| *x < 5).is_empty());
        assert_eq!(r(0, 3).take_while(|_| true).to_vec(), vec![0, 1, 2]);
    }

    #[test]
    fn drop_while_drops_prefix_only() {
        assert_eq!(r(0, 8).drop_while(|x| *x < 5).to_vec(), vec![5, 6, 7]);
        assert!(r(0, 4).drop_while(|_| true).is_empty());
        assert_eq!(r(3, 6).drop_while(|_| false).to_vec(), vec![3, 4, 5]);
    }

    #[test]
    fn scan_running_sum() {
        assert_eq!(r(1, 6).scan(0u32, |a, x| a + x).to_vec(), vec![1, 3, 6, 10, 15]);
        let empty: Stream<u32, LazyEval> = Stream::Empty;
        assert!(empty.scan(0u32, |a, x| a + x).is_empty());
    }

    #[test]
    fn flat_map_concatenates() {
        let s = r(1, 4).flat_map_elems(|&x| Stream::range(LazyEval, 0, x));
        assert_eq!(s.to_vec(), vec![0, 0, 1, 0, 1, 2]);
    }

    #[test]
    fn flat_map_skips_empty_productions() {
        let s = r(0, 6).flat_map_elems(|&x| {
            if x % 2 == 0 {
                Stream::Empty
            } else {
                Stream::singleton(LazyEval, x * 10)
            }
        });
        assert_eq!(s.to_vec(), vec![10, 30, 50]);
    }

    #[test]
    fn interleave_alternates() {
        let a = r(0, 3);
        let b = r(10, 15);
        assert_eq!(a.interleave(&b).to_vec(), vec![0, 10, 1, 11, 2, 12, 13, 14]);
    }

    #[test]
    fn dedup_consecutive_collapses_runs() {
        let s = Stream::from_vec(LazyEval, vec![1, 1, 2, 2, 2, 3, 1, 1]);
        assert_eq!(s.dedup_consecutive().to_vec(), vec![1, 2, 3, 1]);
    }

    #[test]
    fn exists_short_circuits() {
        // A stream long enough that full forcing would be noticeable.
        assert!(r(0, 10_000_000).exists(|x| *x == 3));
        assert!(!r(0, 10).exists(|x| *x == 99));
    }

    #[test]
    fn merge_sorted_merges() {
        let a = Stream::from_vec(LazyEval, vec![1, 4, 6]);
        let b = Stream::from_vec(LazyEval, vec![2, 3, 5, 7]);
        let m = a.merge_sorted(&b, |x, y| x.cmp(y));
        assert_eq!(m.to_vec(), vec![1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn combinators_agree_under_future() {
        let check = |mk: &dyn Fn() -> Vec<u32>, want: &[u32]| assert_eq!(mk(), want);
        let ex = Executor::new(2);
        let eval = FutureEval::new(ex);
        let e2 = eval.clone();
        check(
            &move || {
                Stream::range(e2.clone(), 1, 20)
                    .scan(0u32, |a, x| a + x)
                    .take_while(|x| *x < 30)
                    .to_vec()
            },
            &[1, 3, 6, 10, 15, 21, 28],
        );
        let e3 = eval.clone();
        check(
            &move || {
                let inner = e3.clone();
                Stream::range(e3.clone(), 1, 4)
                    .flat_map_elems(move |&x| Stream::singleton(inner.clone(), x * x))
                    .to_vec()
            },
            &[1, 4, 9],
        );
    }
}

//! Chunked streams — the paper's §7 improvement hypothesis, made
//! first-class.
//!
//! > "since the minimum size of elementary computations seems to be a key
//! > factor, we suppose that grouping these in bigger chunks may provide
//! > better efficiency."
//!
//! A [`ChunkedStream`] is a stream whose elements are `Arc<Vec<T>>`
//! blocks. One suspension (and hence one task under the Future strategy)
//! now covers `chunk_size` elementary operations, amortizing spawn/await
//! overhead — and the per-block computation becomes dense enough to
//! offload to the AOT XLA kernel (see `poly::chunked_mul` and
//! `runtime`).

use std::sync::Arc;

use super::{Elem, Stream};
use crate::susp::Eval;

/// A block of elements traveling through a stream as one unit.
pub type Chunk<T> = Arc<Vec<T>>;

/// Stream of blocks with element-level helpers.
pub struct ChunkedStream<T: Elem, E: Eval> {
    inner: Stream<Chunk<T>, E>,
}

impl<T: Elem, E: Eval> Clone for ChunkedStream<T, E> {
    fn clone(&self) -> Self {
        ChunkedStream { inner: self.inner.clone() }
    }
}

impl<T: Elem, E: Eval> From<Stream<Chunk<T>, E>> for ChunkedStream<T, E> {
    fn from(inner: Stream<Chunk<T>, E>) -> Self {
        ChunkedStream { inner }
    }
}

impl<T: Elem, E: Eval> ChunkedStream<T, E> {
    pub fn empty() -> Self {
        ChunkedStream { inner: Stream::Empty }
    }

    /// Chunk a strict sequence into blocks of `chunk_size`.
    pub fn from_vec(eval: E, items: Vec<T>, chunk_size: usize) -> Self {
        assert!(chunk_size > 0, "chunk_size must be positive");
        let blocks: Vec<Chunk<T>> = items
            .chunks(chunk_size)
            .map(|c| Arc::new(c.to_vec()))
            .collect();
        ChunkedStream { inner: Stream::from_vec(eval, blocks) }
    }

    /// Re-chunk an element stream into blocks of `chunk_size`,
    /// suspension-preserving: each block is assembled inside one
    /// suspension, so under `Future` one task materializes
    /// `chunk_size` upstream cells.
    pub fn from_stream(source: Stream<T, E>, chunk_size: usize) -> Self {
        assert!(chunk_size > 0, "chunk_size must be positive");
        ChunkedStream { inner: Self::rechunk(source, chunk_size) }
    }

    fn rechunk(source: Stream<T, E>, chunk_size: usize) -> Stream<Chunk<T>, E> {
        match source.eval() {
            None => Stream::Empty,
            Some(eval) => {
                let eval = eval.clone();
                // Assemble the first block strictly (mirrors the paper's
                // filter scan), suspend the rest.
                let mut block = Vec::with_capacity(chunk_size);
                let mut cur = source;
                while block.len() < chunk_size {
                    match cur.head() {
                        None => break,
                        Some(h) => {
                            block.push(h.clone());
                            let next = cur.tail().expect("non-empty").clone();
                            cur = next;
                        }
                    }
                }
                if block.is_empty() {
                    return Stream::Empty;
                }
                Stream::cons_with(eval, Arc::new(block), move || {
                    Self::rechunk(cur, chunk_size)
                })
            }
        }
    }

    /// The underlying stream of blocks.
    pub fn blocks(&self) -> &Stream<Chunk<T>, E> {
        &self.inner
    }

    pub fn into_blocks(self) -> Stream<Chunk<T>, E> {
        self.inner
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// Map a function over whole blocks (one suspension per block — this
    /// is where chunking pays off).
    pub fn map_blocks<U, F>(&self, f: F) -> ChunkedStream<U, E>
    where
        U: Elem,
        F: Fn(&[T]) -> Vec<U> + Send + Sync + Clone + 'static,
    {
        ChunkedStream { inner: self.inner.map_elems(move |b| Arc::new(f(b))) }
    }

    /// Map over single elements, still block-granular under the hood.
    pub fn map_elems<U, F>(&self, f: F) -> ChunkedStream<U, E>
    where
        U: Elem,
        F: Fn(&T) -> U + Send + Sync + Clone + 'static,
    {
        self.map_blocks(move |b| b.iter().map(&f).collect())
    }

    /// Filter elements; blocks may shrink (empty blocks are dropped at
    /// flatten time).
    pub fn filter<P>(&self, p: P) -> ChunkedStream<T, E>
    where
        P: Fn(&T) -> bool + Send + Sync + Clone + 'static,
    {
        self.map_blocks(move |b| b.iter().filter(|x| p(x)).cloned().collect())
    }

    /// Flatten back to element granularity (forces progressively).
    pub fn flatten(&self) -> Vec<T> {
        let mut out = Vec::new();
        for block in self.inner.iter() {
            out.extend(block.iter().cloned());
        }
        out
    }

    /// Total number of elements (forces everything).
    pub fn element_count(&self) -> usize {
        self.inner.fold(0, |n, b| n + b.len())
    }

    /// Number of blocks (forces the spine).
    pub fn block_count(&self) -> usize {
        self.inner.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Executor;
    use crate::susp::{FutureEval, LazyEval};

    #[test]
    fn from_vec_blocks_correctly() {
        let cs = ChunkedStream::from_vec(LazyEval, (0..10).collect(), 4);
        assert_eq!(cs.block_count(), 3); // 4 + 4 + 2
        assert_eq!(cs.element_count(), 10);
        assert_eq!(cs.flatten(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn exact_multiple_has_no_ragged_tail() {
        let cs = ChunkedStream::from_vec(LazyEval, (0..8).collect(), 4);
        assert_eq!(cs.block_count(), 2);
    }

    #[test]
    fn empty_input_gives_empty_stream() {
        let cs: ChunkedStream<u32, LazyEval> = ChunkedStream::from_vec(LazyEval, vec![], 4);
        assert!(cs.is_empty());
        assert_eq!(cs.element_count(), 0);
    }

    #[test]
    fn rechunk_stream_preserves_order() {
        let s = Stream::range(LazyEval, 0, 11);
        let cs = ChunkedStream::from_stream(s, 3);
        assert_eq!(cs.flatten(), (0..11).collect::<Vec<_>>());
        assert_eq!(cs.block_count(), 4); // 3+3+3+2
    }

    #[test]
    fn map_blocks_and_elements_agree() {
        let cs = ChunkedStream::from_vec(LazyEval, (1..=9).collect(), 4);
        let via_blocks = cs.map_blocks(|b| b.iter().map(|x| x * 2).collect()).flatten();
        let via_elems = cs.map_elems(|x| x * 2).flatten();
        assert_eq!(via_blocks, via_elems);
    }

    #[test]
    fn filter_shrinks_blocks() {
        let cs = ChunkedStream::from_vec(LazyEval, (0..20).collect(), 5);
        let odd = cs.filter(|x| x % 2 == 1);
        assert_eq!(odd.flatten(), (0..20).filter(|x| x % 2 == 1).collect::<Vec<_>>());
    }

    #[test]
    fn chunked_future_pipeline_matches_lazy() {
        let work = |x: &u32| {
            // Simulate a non-trivial elementary operation.
            let mut acc = *x;
            for _ in 0..10 {
                acc = acc.wrapping_mul(2654435761).rotate_left(3);
            }
            acc
        };
        let lazy = ChunkedStream::from_vec(LazyEval, (0..100).collect(), 16)
            .map_elems(work)
            .flatten();
        let ex = Executor::new(3);
        let fut = ChunkedStream::from_vec(FutureEval::new(ex), (0..100).collect(), 16)
            .map_elems(work)
            .flatten();
        assert_eq!(lazy, fut);
    }

    #[test]
    #[should_panic(expected = "chunk_size")]
    fn zero_chunk_size_panics() {
        let _ = ChunkedStream::from_vec(LazyEval, vec![1u32], 0);
    }
}

//! Chunked streams — the paper's §7 improvement hypothesis, made
//! first-class.
//!
//! > "since the minimum size of elementary computations seems to be a key
//! > factor, we suppose that grouping these in bigger chunks may provide
//! > better efficiency."
//!
//! A [`ChunkedStream`] is a stream whose elements are `Arc<Vec<T>>`
//! blocks. One suspension (and hence one task under the Future strategy)
//! now covers `chunk_size` elementary operations, amortizing spawn/await
//! overhead — and the per-block computation becomes dense enough to
//! offload to the AOT XLA kernel (see `poly::chunked_mul` and
//! `runtime`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::{Elem, Stream};
use crate::susp::Eval;

/// A block of elements traveling through a stream as one unit.
pub type Chunk<T> = Arc<Vec<T>>;

/// Adaptive chunk-size policy.
///
/// The paper's §7 leaves chunk size as a free constant; the right value
/// is a function of the machine, not the workload author: one task
/// should cost enough that spawn/steal/complete overhead (~1 µs on the
/// work-stealing executor) disappears into it, while the input still
/// splits into enough chunks to keep every worker fed. [`ChunkSizer`]
/// encodes both constraints:
///
/// * **cost floor** — `chunk ≥ target_task / per_elem_cost`, with the
///   per-element cost *measured* ([`ChunkSizer::probe_cost`]) rather
///   than guessed;
/// * **coverage ceiling** — at least `oversubscription × parallelism`
///   chunks overall, so stealing has something to balance.
///
/// Used by `poly::chunked_times_adaptive` and
/// `sieve::chunked_primes_adaptive`.
#[derive(Debug, Clone)]
pub struct ChunkSizer {
    /// Aim for one suspension (task) of about this much work.
    pub target_task: Duration,
    /// Never go below this chunk size.
    pub min_chunk: usize,
    /// Never go above this chunk size.
    pub max_chunk: usize,
    /// Minimum chunks per worker; keeps the tail of the run balanced.
    pub oversubscription: usize,
}

impl Default for ChunkSizer {
    fn default() -> Self {
        ChunkSizer {
            target_task: Duration::from_micros(200),
            min_chunk: 1,
            max_chunk: 1 << 16,
            // High enough that, combined with the future cells'
            // MAX_INLINE_DEPTH=8 trampoline segmentation, a fully
            // materialized chunk spine still unwinds with ≥ parallelism
            // concurrent segments (chunk count ≥ 8 × parallelism needs
            // oversubscription ≥ 8; 32 leaves steal-balancing headroom).
            oversubscription: 32,
        }
    }
}

impl ChunkSizer {
    /// Chunk size for `total_elems` elements of measured cost `per_elem`
    /// on `parallelism` workers.
    pub fn pick(&self, per_elem: Duration, total_elems: usize, parallelism: usize) -> usize {
        let per = per_elem.as_nanos().max(1);
        let by_cost = (self.target_task.as_nanos() / per).max(1) as usize;
        let min_chunks = parallelism.max(1) * self.oversubscription.max(1);
        let by_coverage = (total_elems / min_chunks).max(1);
        let hi = self.max_chunk.max(self.min_chunk.max(1));
        by_cost.min(by_coverage).clamp(self.min_chunk.max(1), hi)
    }

    /// Measure per-element cost: run `probe` (which should process
    /// `elems` elements through the real code path) once and divide.
    pub fn probe_cost(elems: usize, probe: impl FnOnce()) -> Duration {
        let t = Instant::now();
        probe();
        t.elapsed() / (elems.max(1) as u32)
    }
}

/// Shareable memo for one [`ChunkSizer::probe_cost`] measurement.
///
/// The probe runs real workload code, so in a long-lived coordinator it
/// must not be re-paid on every job: each shard keeps one `CostCache`
/// per workload and the adaptive entry points
/// (`poly::chunked_times_adaptive_cached`,
/// `sieve::chunked_primes_adaptive_cached`) probe only on the first job
/// routed there. Cloning shares the underlying slot.
#[derive(Debug, Clone, Default)]
pub struct CostCache {
    inner: Arc<std::sync::Mutex<Option<Duration>>>,
}

impl CostCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// The cached per-element cost, if one has been measured.
    pub fn get(&self) -> Option<Duration> {
        *self.inner.lock().unwrap()
    }

    /// Return the cached cost, or run `measure` once and cache its
    /// result. The lock is held across the probe so concurrent first
    /// jobs do not all pay for it.
    pub fn get_or_measure(&self, measure: impl FnOnce() -> Duration) -> Duration {
        let mut slot = self.inner.lock().unwrap();
        match *slot {
            Some(cost) => cost,
            None => {
                let cost = measure();
                *slot = Some(cost);
                cost
            }
        }
    }
}

/// Stream of blocks with element-level helpers.
pub struct ChunkedStream<T: Elem, E: Eval> {
    inner: Stream<Chunk<T>, E>,
}

impl<T: Elem, E: Eval> Clone for ChunkedStream<T, E> {
    fn clone(&self) -> Self {
        ChunkedStream { inner: self.inner.clone() }
    }
}

impl<T: Elem, E: Eval> From<Stream<Chunk<T>, E>> for ChunkedStream<T, E> {
    fn from(inner: Stream<Chunk<T>, E>) -> Self {
        ChunkedStream { inner }
    }
}

impl<T: Elem, E: Eval> ChunkedStream<T, E> {
    pub fn empty() -> Self {
        ChunkedStream { inner: Stream::Empty }
    }

    /// Chunk a strict sequence into blocks of `chunk_size`.
    pub fn from_vec(eval: E, items: Vec<T>, chunk_size: usize) -> Self {
        assert!(chunk_size > 0, "chunk_size must be positive");
        let blocks: Vec<Chunk<T>> = items
            .chunks(chunk_size)
            .map(|c| Arc::new(c.to_vec()))
            .collect();
        ChunkedStream { inner: Stream::from_vec(eval, blocks) }
    }

    /// Re-chunk an element stream into blocks of `chunk_size`,
    /// suspension-preserving: each block is assembled inside one
    /// suspension, so under `Future` one task materializes
    /// `chunk_size` upstream cells.
    pub fn from_stream(source: Stream<T, E>, chunk_size: usize) -> Self {
        assert!(chunk_size > 0, "chunk_size must be positive");
        ChunkedStream { inner: Self::rechunk(source, chunk_size) }
    }

    fn rechunk(source: Stream<T, E>, chunk_size: usize) -> Stream<Chunk<T>, E> {
        match source.eval() {
            None => Stream::Empty,
            Some(eval) => {
                let eval = eval.clone();
                // Assemble the first block strictly (mirrors the paper's
                // filter scan), suspend the rest.
                let mut block = Vec::with_capacity(chunk_size);
                let mut cur = source;
                while block.len() < chunk_size {
                    match cur.head() {
                        None => break,
                        Some(h) => {
                            block.push(h.clone());
                            let next = cur.tail().expect("non-empty").clone();
                            cur = next;
                        }
                    }
                }
                if block.is_empty() {
                    return Stream::Empty;
                }
                Stream::cons_with(eval, Arc::new(block), move || {
                    Self::rechunk(cur, chunk_size)
                })
            }
        }
    }

    /// The underlying stream of blocks.
    pub fn blocks(&self) -> &Stream<Chunk<T>, E> {
        &self.inner
    }

    pub fn into_blocks(self) -> Stream<Chunk<T>, E> {
        self.inner
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// Map a function over whole blocks (one suspension per block — this
    /// is where chunking pays off).
    pub fn map_blocks<U, F>(&self, f: F) -> ChunkedStream<U, E>
    where
        U: Elem,
        F: Fn(&[T]) -> Vec<U> + Send + Sync + Clone + 'static,
    {
        ChunkedStream { inner: self.inner.map_elems(move |b| Arc::new(f(b))) }
    }

    /// Map over single elements, still block-granular under the hood.
    pub fn map_elems<U, F>(&self, f: F) -> ChunkedStream<U, E>
    where
        U: Elem,
        F: Fn(&T) -> U + Send + Sync + Clone + 'static,
    {
        self.map_blocks(move |b| b.iter().map(&f).collect())
    }

    /// Filter elements; blocks may shrink (empty blocks are dropped at
    /// flatten time).
    pub fn filter<P>(&self, p: P) -> ChunkedStream<T, E>
    where
        P: Fn(&T) -> bool + Send + Sync + Clone + 'static,
    {
        self.map_blocks(move |b| b.iter().filter(|x| p(x)).cloned().collect())
    }

    /// Flatten back to element granularity (forces progressively).
    pub fn flatten(&self) -> Vec<T> {
        let mut out = Vec::new();
        for block in self.inner.iter() {
            out.extend(block.iter().cloned());
        }
        out
    }

    /// Total number of elements (forces everything).
    pub fn element_count(&self) -> usize {
        self.inner.fold(0, |n, b| n + b.len())
    }

    /// Number of blocks (forces the spine).
    pub fn block_count(&self) -> usize {
        self.inner.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Executor;
    use crate::susp::{FutureEval, LazyEval};

    #[test]
    fn from_vec_blocks_correctly() {
        let cs = ChunkedStream::from_vec(LazyEval, (0..10).collect(), 4);
        assert_eq!(cs.block_count(), 3); // 4 + 4 + 2
        assert_eq!(cs.element_count(), 10);
        assert_eq!(cs.flatten(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn exact_multiple_has_no_ragged_tail() {
        let cs = ChunkedStream::from_vec(LazyEval, (0..8).collect(), 4);
        assert_eq!(cs.block_count(), 2);
    }

    #[test]
    fn empty_input_gives_empty_stream() {
        let cs: ChunkedStream<u32, LazyEval> = ChunkedStream::from_vec(LazyEval, vec![], 4);
        assert!(cs.is_empty());
        assert_eq!(cs.element_count(), 0);
    }

    #[test]
    fn rechunk_stream_preserves_order() {
        let s = Stream::range(LazyEval, 0, 11);
        let cs = ChunkedStream::from_stream(s, 3);
        assert_eq!(cs.flatten(), (0..11).collect::<Vec<_>>());
        assert_eq!(cs.block_count(), 4); // 3+3+3+2
    }

    #[test]
    fn map_blocks_and_elements_agree() {
        let cs = ChunkedStream::from_vec(LazyEval, (1..=9).collect(), 4);
        let via_blocks = cs.map_blocks(|b| b.iter().map(|x| x * 2).collect()).flatten();
        let via_elems = cs.map_elems(|x| x * 2).flatten();
        assert_eq!(via_blocks, via_elems);
    }

    #[test]
    fn filter_shrinks_blocks() {
        let cs = ChunkedStream::from_vec(LazyEval, (0..20).collect(), 5);
        let odd = cs.filter(|x| x % 2 == 1);
        assert_eq!(odd.flatten(), (0..20).filter(|x| x % 2 == 1).collect::<Vec<_>>());
    }

    #[test]
    fn chunked_future_pipeline_matches_lazy() {
        let work = |x: &u32| {
            // Simulate a non-trivial elementary operation.
            let mut acc = *x;
            for _ in 0..10 {
                acc = acc.wrapping_mul(2654435761).rotate_left(3);
            }
            acc
        };
        let lazy = ChunkedStream::from_vec(LazyEval, (0..100).collect(), 16)
            .map_elems(work)
            .flatten();
        let ex = Executor::new(3);
        let fut = ChunkedStream::from_vec(FutureEval::new(ex), (0..100).collect(), 16)
            .map_elems(work)
            .flatten();
        assert_eq!(lazy, fut);
    }

    #[test]
    #[should_panic(expected = "chunk_size")]
    fn zero_chunk_size_panics() {
        let _ = ChunkedStream::from_vec(LazyEval, vec![1u32], 0);
    }

    #[test]
    fn sizer_respects_cost_floor() {
        let sizer = ChunkSizer::default(); // 200µs target
        // 1µs elements → ~200 per chunk (coverage cap not binding).
        let c = sizer.pick(std::time::Duration::from_micros(1), 1_000_000, 4);
        assert_eq!(c, 200);
        // 1ms elements → chunk of 1.
        let c = sizer.pick(std::time::Duration::from_millis(1), 1_000_000, 4);
        assert_eq!(c, 1);
    }

    #[test]
    fn sizer_respects_coverage_ceiling() {
        let sizer = ChunkSizer::default();
        // Nearly-free elements, small input: coverage (4 workers × 32
        // oversubscription = 128 chunks) binds before cost does.
        let c = sizer.pick(std::time::Duration::from_nanos(1), 12_800, 4);
        assert_eq!(c, 100);
        // Tiny input never yields chunk 0.
        let c = sizer.pick(std::time::Duration::from_nanos(1), 3, 8);
        assert_eq!(c, 1);
    }

    #[test]
    fn sizer_clamps_to_bounds() {
        let sizer = ChunkSizer {
            min_chunk: 8,
            max_chunk: 64,
            ..ChunkSizer::default()
        };
        let c = sizer.pick(std::time::Duration::from_nanos(1), usize::MAX, 1);
        assert_eq!(c, 64);
        let c = sizer.pick(std::time::Duration::from_secs(1), usize::MAX, 1);
        assert_eq!(c, 8);
    }

    #[test]
    fn cost_cache_measures_once_and_shares() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let cache = CostCache::new();
        assert_eq!(cache.get(), None);
        let probes = AtomicUsize::new(0);
        let measured = std::time::Duration::from_micros(7);
        let a = cache.get_or_measure(|| {
            probes.fetch_add(1, Ordering::SeqCst);
            measured
        });
        // Clones share the slot: no second probe.
        let b = cache.clone().get_or_measure(|| {
            probes.fetch_add(1, Ordering::SeqCst);
            std::time::Duration::from_secs(9)
        });
        assert_eq!(a, measured);
        assert_eq!(b, measured);
        assert_eq!(probes.load(Ordering::SeqCst), 1);
        assert_eq!(cache.get(), Some(measured));
    }

    #[test]
    fn probe_cost_measures_something() {
        let per = ChunkSizer::probe_cost(1000, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i).wrapping_mul(31));
            }
            std::hint::black_box(acc);
        });
        // Sane bounds: sub-second per element, not zero-cost overall.
        assert!(per < std::time::Duration::from_secs(1));
    }
}

//! Data-parallel collections substrate — the paper's *control* technique.
//!
//! The `list` / `list_big` rows of Table 1 use "a more classical
//! parallelization technique, based on parallel collections" [4,8]:
//! SIMD-style data parallelism (one operation applied independently to
//! many elements), in contrast to the stream pipeline's task parallelism.
//! Scala gets this from `par`; offline Rust gets it here: fork-join
//! `par_map` and `par_reduce` over an [`Executor`].

use crate::exec::Executor;
use crate::susp::{Fut, Susp};

/// Apply `f` to every element, fanning chunks out over `exec`.
/// Preserves order.
pub fn par_map<T, U, F>(exec: &Executor, items: &[T], f: F) -> Vec<U>
where
    T: Clone + Send + Sync + 'static,
    U: Clone + Send + Sync + 'static,
    F: Fn(&T) -> U + Send + Sync + Clone + 'static,
{
    if items.is_empty() {
        return Vec::new();
    }
    let chunk = split_size(items.len(), exec.parallelism());
    let futs: Vec<Fut<Vec<U>>> = items
        .chunks(chunk)
        .map(|c| {
            let c = c.to_vec();
            let f = f.clone();
            Fut::spawn(exec, move || c.iter().map(&f).collect())
        })
        .collect();
    let mut out = Vec::with_capacity(items.len());
    for fut in futs {
        out.extend(fut.force().iter().cloned());
    }
    out
}

/// Tree-reduce with an associative `merge`; `identity` for the empty
/// input. Matches how Scala's aggregate combines per-chunk results.
pub fn par_reduce<T, F>(exec: &Executor, mut items: Vec<T>, identity: T, merge: F) -> T
where
    T: Clone + Send + Sync + 'static,
    F: Fn(&T, &T) -> T + Send + Sync + Clone + 'static,
{
    if items.is_empty() {
        return identity;
    }
    while items.len() > 1 {
        let mut next: Vec<Fut<T>> = Vec::with_capacity(items.len().div_ceil(2));
        let mut it = items.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => {
                    let merge = merge.clone();
                    next.push(Fut::spawn(exec, move || merge(&a, &b)));
                }
                None => next.push(Fut::ready(exec, a)),
            }
        }
        items = next.into_iter().map(|f| f.force().clone()).collect();
    }
    items.pop().unwrap()
}

/// `par_map` then `par_reduce` without materializing twice.
pub fn par_map_reduce<T, U, F, M>(
    exec: &Executor,
    items: &[T],
    f: F,
    identity: U,
    merge: M,
) -> U
where
    T: Clone + Send + Sync + 'static,
    U: Clone + Send + Sync + 'static,
    F: Fn(&T) -> U + Send + Sync + Clone + 'static,
    M: Fn(&U, &U) -> U + Send + Sync + Clone + 'static,
{
    let mapped = par_map(exec, items, f);
    par_reduce(exec, mapped, identity, merge)
}

/// Chunk size giving ~4 chunks per worker (limits stragglers without
/// drowning the queue).
fn split_size(len: usize, parallelism: usize) -> usize {
    (len / (parallelism * 4).max(1)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let ex = Executor::new(4);
        let xs: Vec<u64> = (0..1000).collect();
        let got = par_map(&ex, &xs, |x| x * x + 1);
        let want: Vec<u64> = xs.iter().map(|x| x * x + 1).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_map_empty() {
        let ex = Executor::new(2);
        let got: Vec<u64> = par_map(&ex, &[] as &[u64], |x| *x);
        assert!(got.is_empty());
    }

    #[test]
    fn par_map_single_worker() {
        let ex = Executor::new(1);
        let xs: Vec<u32> = (0..50).collect();
        assert_eq!(par_map(&ex, &xs, |x| x + 1), (1..51).collect::<Vec<_>>());
    }

    #[test]
    fn par_reduce_sums() {
        let ex = Executor::new(4);
        let xs: Vec<u64> = (1..=100).collect();
        let got = par_reduce(&ex, xs, 0, |a, b| a + b);
        assert_eq!(got, 5050);
    }

    #[test]
    fn par_reduce_empty_gives_identity() {
        let ex = Executor::new(2);
        assert_eq!(par_reduce(&ex, Vec::<u64>::new(), 42, |a, b| a + b), 42);
    }

    #[test]
    fn par_reduce_single() {
        let ex = Executor::new(2);
        assert_eq!(par_reduce(&ex, vec![7u64], 0, |a, b| a + b), 7);
    }

    #[test]
    fn par_map_reduce_composes() {
        let ex = Executor::new(3);
        let xs: Vec<u64> = (0..37).collect();
        let got = par_map_reduce(&ex, &xs, |x| x * 2, 0, |a, b| a + b);
        assert_eq!(got, 36 * 37);
    }

    #[test]
    fn order_preserved_with_odd_sizes() {
        let ex = Executor::new(5);
        for len in [1usize, 2, 3, 17, 101] {
            let xs: Vec<usize> = (0..len).collect();
            assert_eq!(par_map(&ex, &xs, |x| *x), xs, "len={len}");
        }
    }
}

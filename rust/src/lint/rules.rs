//! The individual lint rules. All of them are line-oriented: a tiny
//! lexical pass (string contents blanked, trailing `//` comments cut)
//! is enough for the invariants checked here, and keeps the linter
//! dependency-free. Note the linter lints its own sources too — rule
//! needles are assembled at runtime (`format!(".{m}(")`) precisely so
//! they never appear verbatim in this file's code.

use super::Finding;

/// `"` as an escape, so this file's own lexical pass never trips over
/// a raw quote inside a char literal.
const QUOTE: char = '\u{22}';

/// Index of the first `#[cfg(test)]` line (in-crate unit-test modules
/// run to EOF in this codebase); source rules stop there.
pub(crate) fn cfg_test_start(lines: &[&str]) -> usize {
    lines
        .iter()
        .position(|l| l.trim() == "#[cfg(test)]")
        .unwrap_or(lines.len())
}

/// The line with string contents blanked (quotes kept) and any
/// trailing `//` comment removed — the "is this real code?" view.
pub(crate) fn code_part(line: &str) -> String {
    let mut out = String::new();
    let mut chars = line.chars().peekable();
    let mut in_str = false;
    let mut escape = false;
    while let Some(c) = chars.next() {
        if in_str {
            if escape {
                escape = false;
            } else if c == '\\' {
                escape = true;
            } else if c == QUOTE {
                in_str = false;
                out.push(QUOTE);
            }
            continue;
        }
        if c == QUOTE {
            in_str = true;
            out.push(QUOTE);
        } else if c == '/' && chars.peek() == Some(&'/') {
            break;
        } else {
            out.push(c);
        }
    }
    out
}

fn contains_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(p) = code[from..].find(word) {
        let start = from + p;
        let end = start + word.len();
        let before_ok = start == 0
            || !(bytes[start - 1].is_ascii_alphanumeric() || bytes[start - 1] == b'_');
        let after_ok = end == code.len()
            || !(bytes[end].is_ascii_alphanumeric() || bytes[end] == b'_');
        if before_ok && after_ok {
            return true;
        }
        from = end;
    }
    false
}

/// Rule `unsafe-safety`: see the module docs for the acceptance forms.
pub(crate) fn unsafe_rule(file: &str, lines: &[&str], skip_from: usize) -> Vec<Finding> {
    let mut out = Vec::new();
    for i in 0..lines.len().min(skip_from) {
        if !contains_word(&code_part(lines[i]), "unsafe") {
            continue;
        }
        if lines[i].contains("SAFETY:") {
            // Trailing justification on the line itself.
            continue;
        }
        if covered_above(lines, i) {
            continue;
        }
        out.push(Finding {
            rule: "unsafe-safety",
            file: file.to_string(),
            line: i + 1,
            message: format!(
                "`unsafe` without an immediately preceding `// SAFETY:` comment: `{}`",
                lines[i].trim()
            ),
        });
    }
    out
}

/// Walk upward from line `i`: attributes are transparent, an adjacent
/// `unsafe` line passes coverage along (one argument may cover a
/// `Send`/`Sync` impl pair), and the first comment block decides —
/// accepted iff it mentions `SAFETY:` (or `# Safety`, the doc-section
/// form for `unsafe fn`).
fn covered_above(lines: &[&str], mut i: usize) -> bool {
    loop {
        if i == 0 {
            return false;
        }
        let mut k = i - 1;
        while lines[k].trim().starts_with("#[") || lines[k].trim().starts_with("#![") {
            if k == 0 {
                return false;
            }
            k -= 1;
        }
        let t = lines[k].trim();
        if t.starts_with("//") {
            let mut j = k;
            while j > 0 && lines[j - 1].trim().starts_with("//") {
                j -= 1;
            }
            return lines[j..=k]
                .iter()
                .any(|l| l.contains("SAFETY:") || l.contains("# Safety"));
        }
        if contains_word(&code_part(lines[k]), "unsafe") {
            i = k;
            continue;
        }
        return false;
    }
}

/// The documented metric-name families (`*` = one arbitrary segment).
const FAMILIES: &[&[&str]] = &[
    &["jobs", "*"],
    &["ingress", "*"],
    &["breaker", "*", "open"],
    &["shard", "*", "*"],
    &["wire", "*"],
    &["wire", "*", "*"],
    &["job", "*", "*"],
];

fn name_matches_taxonomy(name: &str) -> bool {
    let segs: Vec<&str> = name.split('.').collect();
    FAMILIES.iter().any(|fam| {
        fam.len() == segs.len()
            && fam
                .iter()
                .zip(&segs)
                .all(|(f, s)| *f == "*" || *s == "*" || f == s)
    })
}

/// `format!` placeholders (`{..}`) become `*` wildcard text.
fn wildcard_placeholders(name: &str) -> String {
    let mut out = String::new();
    let mut depth = 0usize;
    for c in name.chars() {
        match c {
            '{' => {
                if depth == 0 {
                    out.push('*');
                }
                depth += 1;
            }
            '}' => depth = depth.saturating_sub(1),
            c if depth == 0 => out.push(c),
            _ => {}
        }
    }
    out
}

/// The first string literal's contents after byte `from`, if any.
fn first_string_literal(raw: &str, from: usize) -> Option<String> {
    let rest = &raw[from..];
    let start = rest.find(QUOTE)? + 1;
    let mut out = String::new();
    let mut escape = false;
    for c in rest[start..].chars() {
        if escape {
            escape = false;
            out.push(c);
        } else if c == '\\' {
            escape = true;
        } else if c == QUOTE {
            return Some(out);
        } else {
            out.push(c);
        }
    }
    None
}

/// Rule `metrics-taxonomy`: every literal metric name registered via a
/// `.counter(`/`.gauge(`/`.timer(`/`.histogram(` method call (incl.
/// `&format!(..)` forms) must match a documented family.
pub(crate) fn metrics_rule(file: &str, lines: &[&str], skip_from: usize) -> Vec<Finding> {
    let needles: Vec<String> = ["counter", "gauge", "timer", "histogram"]
        .iter()
        .map(|m| format!(".{m}("))
        .collect();
    let mut out = Vec::new();
    for i in 0..lines.len().min(skip_from) {
        let raw = lines[i];
        let code = code_part(raw);
        for needle in &needles {
            if !code.contains(needle.as_str()) {
                continue;
            }
            let Some(pos) = raw.find(needle.as_str()) else { continue };
            // A call with no literal on the line (dynamic name or
            // wrapped argument) is out of this rule's static reach.
            let Some(name) = first_string_literal(raw, pos + needle.len()) else {
                continue;
            };
            let normalized = wildcard_placeholders(&name);
            if !name_matches_taxonomy(&normalized) {
                out.push(Finding {
                    rule: "metrics-taxonomy",
                    file: file.to_string(),
                    line: i + 1,
                    message: format!(
                        "metric name `{name}` does not match the documented taxonomy \
                         (jobs.* / ingress.* / breaker.*.open / shard.*.* / wire.* / \
                         wire.*.* / job.*.*)"
                    ),
                });
            }
        }
    }
    out
}

/// Rule `err-line`: integration tests must parse wire error lines via
/// `testkit::wire` instead of ad-hoc string matching.
pub(crate) fn errline_rule(file: &str, lines: &[&str]) -> Vec<Finding> {
    let needles: Vec<String> = [
        format!("starts_with({QUOTE}err"),
        format!("contains({QUOTE}err"),
        format!("== {QUOTE}err"),
        format!("== format!({QUOTE}err"),
    ]
    .into();
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        if raw.trim().starts_with("//") {
            continue;
        }
        if raw.contains("parse_err_line") || raw.contains("ErrLine") {
            continue;
        }
        if needles.iter().any(|n| raw.contains(n.as_str())) {
            out.push(Finding {
                rule: "err-line",
                file: file.to_string(),
                line: i + 1,
                message: "ad-hoc err-line string match; parse it with \
                          testkit::wire::parse_err_line / ErrLine"
                    .to_string(),
            });
        }
    }
    out
}

/// Canonical `Config` keys: the first literal of every
/// `"key" | "dotted.alias" =>` match arm in `config/mod.rs` (the dotted
/// second literal is what distinguishes the key table from other
/// string matches).
pub(crate) fn config_keys(config_src: &str) -> Vec<String> {
    let mut keys: Vec<String> = Vec::new();
    for line in config_src.lines() {
        let t = line.trim();
        if !t.starts_with(QUOTE) {
            continue;
        }
        let Some(end) = t[1..].find(QUOTE) else { continue };
        let key = &t[1..1 + end];
        let rest = t[2 + end..].trim_start();
        let Some(rest) = rest.strip_prefix('|') else { continue };
        let rest = rest.trim_start();
        if !rest.starts_with(QUOTE) {
            continue;
        }
        let Some(end2) = rest[1..].find(QUOTE) else { continue };
        let alias = &rest[1..1 + end2];
        if alias.contains('.') && !keys.iter().any(|k| k == key) {
            keys.push(key.to_string());
        }
    }
    keys
}

/// Rule `config-keys`: every canonical key must appear in the `--help`
/// text (anywhere in `main.rs`) and in the `coordinator/mod.rs` module
/// docs (`//!` lines).
pub(crate) fn config_rule(config_src: &str, main_src: &str, coord_src: &str) -> Vec<Finding> {
    let keys = config_keys(config_src);
    let coord_docs: String = coord_src
        .lines()
        .filter(|l| l.trim_start().starts_with("//!"))
        .collect::<Vec<_>>()
        .join("\n");
    let mut out = Vec::new();
    for key in keys {
        if !main_src.contains(&key) {
            out.push(Finding {
                rule: "config-keys",
                file: "rust/src/main.rs".to_string(),
                line: 0,
                message: format!("config key `{key}` is missing from the --help text"),
            });
        }
        if !coord_docs.contains(&key) {
            out.push(Finding {
                rule: "config-keys",
                file: "rust/src/coordinator/mod.rs".to_string(),
                line: 0,
                message: format!(
                    "config key `{key}` is missing from the module docs configuration \
                     reference"
                ),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn code_part_blanks_strings_and_cuts_comments() {
        assert_eq!(code_part(r#"let x = "unsafe"; // unsafe note"#), r#"let x = ""; "#);
        assert_eq!(code_part("unsafe { x() }"), "unsafe { x() }");
    }

    #[test]
    fn unsafe_rule_accepts_justified_forms() {
        let lines = vec![
            "// SAFETY: fd is owned.",
            "unsafe { close(fd) };",
            "let x = unsafe { y() }; // SAFETY: y upholds z.",
            "/// Docs.",
            "///",
            "/// # Safety",
            "///",
            "/// Owner-only.",
            "#[inline]",
            "pub unsafe fn push(&self) {}",
            "// SAFETY: both impls: the pin protocol serializes access.",
            "unsafe impl Send for T {}",
            "unsafe impl Sync for T {}",
        ];
        assert!(unsafe_rule("f.rs", &lines, lines.len()).is_empty());
    }

    #[test]
    fn unsafe_rule_flags_bare_blocks() {
        let lines = vec!["let fd = open();", "unsafe { close(fd) };"];
        let findings = unsafe_rule("f.rs", &lines, lines.len());
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].line, 2);
        assert_eq!(findings[0].rule, "unsafe-safety");
    }

    #[test]
    fn unsafe_rule_ignores_strings_comments_and_tests() {
        let lines = vec![
            r#"let s = "unsafe";"#,
            "// unsafe in a comment",
            "#[cfg(test)]",
            "unsafe { never_checked() };",
        ];
        assert!(unsafe_rule("f.rs", &lines, cfg_test_start(&lines)).is_empty());
    }

    #[test]
    fn metrics_rule_checks_taxonomy() {
        let good = vec![
            r#"m.counter("jobs.completed").inc();"#,
            r#"m.gauge(&format!("shard.{sid}.queue_depth")).set(1);"#,
            r#"m.gauge(&format!("breaker.{workload}.open")).set(1);"#,
            r#"m.counter(&format!("wire.{r}.frames_in"));"#,
            r#"m.timer(&format!("job.{}.{}", w, mode));"#,
            r#"m.counter(dynamic_name).inc();"#,
        ];
        assert!(metrics_rule("f.rs", &good, good.len()).is_empty());
        let bad = vec![r#"m.counter("queue.depth").inc();"#];
        let findings = metrics_rule("f.rs", &bad, bad.len());
        assert_eq!(findings.len(), 1);
        assert!(findings[0].message.contains("queue.depth"));
    }

    #[test]
    fn errline_rule_flags_adhoc_matching() {
        let lines = vec![
            r#"assert!(line.starts_with("err timeout"));"#,
            r#"assert!(parse_err_line(&line) == Some(ErrLine::Timeout));"#,
            r#"let ok = l == format!("err closed ticket={id}");"#,
        ];
        let findings = errline_rule("rust/tests/t.rs", &lines);
        assert_eq!(findings.len(), 2);
        assert_eq!(findings[0].line, 1);
        assert_eq!(findings[1].line, 3);
    }

    #[test]
    fn config_keys_extracted_from_match_arms() {
        let src = r#"
            match key {
                "primes_n" | "primes.n" => {}
                "shards" | "coordinator.shards" => {}
                "framed" | "frame" | "binary" => {}
            }
        "#;
        assert_eq!(config_keys(src), vec!["primes_n".to_string(), "shards".to_string()]);
    }

    #[test]
    fn config_rule_reports_both_sides() {
        let config = r#""alpha_key" | "a.b" => {}"#;
        let findings = config_rule(config, "no mention", "//! no mention either");
        assert_eq!(findings.len(), 2);
        assert!(findings.iter().all(|f| f.message.contains("alpha_key")));
    }
}

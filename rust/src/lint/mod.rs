//! `sfut lint` — repo-invariant static analysis for this crate's own
//! sources.
//!
//! A small line-oriented pass (std only, no external parser) that walks
//! `rust/src/**/*.rs` and `rust/tests/*.rs` and enforces the invariants
//! the codebase documents but the compiler cannot check:
//!
//! * **`unsafe-safety`** — every `unsafe` block / `unsafe fn` /
//!   `unsafe impl` in non-test source must be justified where it
//!   stands: a trailing `// SAFETY:` on the same line, an immediately
//!   preceding comment block containing `SAFETY:` (attributes and
//!   adjacent `unsafe impl` lines may sit between — one argument may
//!   cover a `Send`/`Sync` pair), or, for `unsafe fn`, a doc block with
//!   a `# Safety` section.
//! * **`metrics-taxonomy`** — every metric name literal passed to
//!   `.counter(` / `.gauge(` / `.timer(` / `.histogram(` (including
//!   `&format!(..)` forms, whose `{..}` placeholders are treated as
//!   wildcard segments) must match the documented taxonomy (see
//!   "Metrics taxonomy" in `coordinator/mod.rs`): `jobs.<event>`,
//!   `ingress.<event>`, `breaker.<workload>.open`, `shard.<id>.<stat>`,
//!   `wire.<stat>`, `wire.<reactor>.<stat>`, `job.<workload>.<mode>`.
//! * **`config-keys`** — every `Config` key (the canonical first
//!   literal of each `set()` match arm in `config/mod.rs`) must appear
//!   in both the `--help` text (`main.rs`) and the `coordinator/mod.rs`
//!   module docs, so the three never drift.
//! * **`err-line`** — integration tests must not match wire error
//!   lines with ad-hoc string tests (`starts_with("err..`,
//!   `== format!("err..` and friends); they go through
//!   `testkit::wire::ErrLine` / `parse_err_line`, the single parser the
//!   protocol owns.
//!
//! In-crate `#[cfg(test)]` modules are exempt from the source rules
//! (unit tests exercise raw corners deliberately); the `err-line` rule
//! applies to `rust/tests/` only.
//!
//! Deliberate exceptions live in `ci/lint_allowlist.txt`, one per line:
//! `<rule> <path-suffix> <message-substring|*>`. Findings print
//! human-readable by default, one JSON object per line with `--json`;
//! the CLI exits non-zero if any finding survives the allowlist.

mod rules;

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

/// One rule violation at a source location.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Rule id (`unsafe-safety`, `metrics-taxonomy`, `config-keys`,
    /// `err-line`).
    pub rule: &'static str,
    /// Repo-relative path.
    pub file: String,
    /// 1-based line (0 for file-level findings).
    pub line: usize,
    pub message: String,
}

impl Finding {
    /// `rule:file:line: message` — the human-readable form.
    pub fn render(&self) -> String {
        format!("{}:{}:{}: {}", self.rule, self.file, self.line, self.message)
    }

    /// One JSON object (hand-serialized; findings are plain strings).
    pub fn render_json(&self) -> String {
        format!(
            "{{\"rule\":\"{}\",\"file\":\"{}\",\"line\":{},\"message\":\"{}\"}}",
            json_escape(self.rule),
            json_escape(&self.file),
            self.line,
            json_escape(&self.message)
        )
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Deliberate exceptions: `<rule> <path-suffix> <message-substring|*>`
/// per line; `#` starts a comment.
pub struct Allowlist {
    entries: Vec<(String, String, String)>,
}

impl Allowlist {
    pub fn load(path: &Path) -> Result<Self> {
        let text = match fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
            Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
        };
        Ok(Self::parse(&text))
    }

    pub fn parse(text: &str) -> Self {
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.splitn(3, char::is_whitespace);
            if let (Some(rule), Some(path), Some(token)) =
                (parts.next(), parts.next(), parts.next())
            {
                entries.push((rule.to_string(), path.to_string(), token.trim().to_string()));
            }
        }
        Allowlist { entries }
    }

    pub fn matches(&self, f: &Finding) -> bool {
        self.entries.iter().any(|(rule, path, token)| {
            rule == f.rule
                && f.file.ends_with(path.as_str())
                && (token == "*" || f.message.contains(token.as_str()))
        })
    }
}

/// Run every rule over the repo rooted at `root` (the directory holding
/// `rust/src`), applying the allowlist at `ci/lint_allowlist.txt`.
/// Returns surviving findings, sorted by file and line.
pub fn run(root: &Path) -> Result<Vec<Finding>> {
    let src_root = root.join("rust/src");
    ensure!(
        src_root.is_dir(),
        "rust/src not found under {} — run `sfut lint` from the repo root",
        root.display()
    );
    let mut files = Vec::new();
    walk(&src_root, &mut files)?;
    let tests_root = root.join("rust/tests");
    if tests_root.is_dir() {
        walk(&tests_root, &mut files)?;
    }
    files.sort();

    let mut findings = Vec::new();
    for file in &files {
        let text =
            fs::read_to_string(file).with_context(|| format!("reading {}", file.display()))?;
        let lines: Vec<&str> = text.lines().collect();
        let rel = file
            .strip_prefix(root)
            .unwrap_or(file)
            .to_string_lossy()
            .replace('\\', "/");
        if rel.starts_with("rust/tests/") {
            findings.extend(rules::errline_rule(&rel, &lines));
        } else {
            let skip = rules::cfg_test_start(&lines);
            findings.extend(rules::unsafe_rule(&rel, &lines, skip));
            findings.extend(rules::metrics_rule(&rel, &lines, skip));
        }
    }

    let config_src = fs::read_to_string(root.join("rust/src/config/mod.rs"))
        .context("reading rust/src/config/mod.rs")?;
    let main_src =
        fs::read_to_string(root.join("rust/src/main.rs")).context("reading rust/src/main.rs")?;
    let coord_src = fs::read_to_string(root.join("rust/src/coordinator/mod.rs"))
        .context("reading rust/src/coordinator/mod.rs")?;
    findings.extend(rules::config_rule(&config_src, &main_src, &coord_src));

    let allow = Allowlist::load(&root.join("ci/lint_allowlist.txt"))?;
    let mut findings: Vec<Finding> =
        findings.into_iter().filter(|f| !allow.matches(f)).collect();
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(findings)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allowlist_parses_and_matches() {
        let allow = Allowlist::parse(
            "# comment\n\nunsafe-safety src/foo.rs raw fd\nmetrics-taxonomy src/bar.rs *\n",
        );
        let f = Finding {
            rule: "unsafe-safety",
            file: "rust/src/foo.rs".into(),
            line: 3,
            message: "unsafe without SAFETY comment (raw fd)".into(),
        };
        assert!(allow.matches(&f));
        let g = Finding { rule: "metrics-taxonomy", file: "rust/src/bar.rs".into(), line: 1, message: "anything".into() };
        assert!(allow.matches(&g));
        let h = Finding { rule: "err-line", file: "rust/src/foo.rs".into(), line: 1, message: "raw fd".into() };
        assert!(!allow.matches(&h));
    }

    #[test]
    fn json_rendering_escapes() {
        let f = Finding {
            rule: "err-line",
            file: "rust/tests/a.rs".into(),
            line: 7,
            message: "bad \"quote\"".into(),
        };
        assert_eq!(
            f.render_json(),
            "{\"rule\":\"err-line\",\"file\":\"rust/tests/a.rs\",\"line\":7,\
             \"message\":\"bad \\\"quote\\\"\"}"
        );
    }

    #[test]
    fn the_tree_lints_clean() {
        // The repo's own invariant: the committed tree has no findings
        // (CI runs the same thing as a blocking step). Skip quietly if
        // the test is executed from an unexpected cwd.
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
        let findings = run(root).expect("lint run");
        assert!(
            findings.is_empty(),
            "lint findings in tree:\n{}",
            findings.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
        );
    }
}

//! # stream-future
//!
//! Production-grade reproduction of **"Parallelizing Stream with Future"**
//! (Raphaël Jolly, 2013): a lazily-evaluated stream whose cons-cell tail
//! is abstracted over a *suspension monad*, so that substituting `Future`
//! for `Lazy` turns any stream-expressed algorithm into a pipeline-
//! parallel one.
//!
//! Architecture (three layers):
//!
//! * **L3 (this crate)** — the stream/future machinery, the executor, the
//!   paper's two applications (prime sieve, sparse polynomial
//!   multiplication), the data-parallel baseline, the chunking extension
//!   (§7), and the coordinator/benchmark harness that regenerates the
//!   paper's Table 1 and Figures 3–4.
//! * **L2 (python/compile/model.py)** — JAX graphs for the dense per-chunk
//!   block computations, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the block
//!   outer-product and sieve-mask hot spots, called by L2.
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT so the Rust
//! hot path can offload chunk products; Python never runs at request
//! time.

pub mod bench_harness;
pub mod bigint;
pub mod config;
pub mod coordinator;
pub mod exec;
pub mod logging;
pub mod metrics;
pub mod par;
pub mod poly;
pub mod rational;
pub mod runtime;
pub mod sieve;
pub mod stream;
pub mod susp;
pub mod testkit;
pub mod workload;

/// The most common imports, bundled.
pub mod prelude {
    pub use crate::config::{Config, Mode, Workload};
    pub use crate::exec::Executor;
    pub use crate::stream::Stream;
    pub use crate::susp::{Eval, FutureEval, LazyEval, StrictEval, Susp};
}

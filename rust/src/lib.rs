//! # stream-future
//!
//! Production-grade reproduction of **"Parallelizing Stream with Future"**
//! (Raphaël Jolly, 2013): a lazily-evaluated stream whose cons-cell tail
//! is abstracted over a *suspension monad*, so that substituting `Future`
//! for `Lazy` turns any stream-expressed algorithm into a pipeline-
//! parallel one.
//!
//! Architecture (three layers):
//!
//! * **L3 (this crate)** — the stream/future machinery, the executor, the
//!   paper's two applications (prime sieve, sparse polynomial
//!   multiplication), the data-parallel baseline, the chunking extension
//!   (§7), and the coordinator/benchmark harness that regenerates the
//!   paper's Table 1 and Figures 3–4.
//! * **L2 (python/compile/model.py)** — JAX graphs for the dense per-chunk
//!   block computations, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the block
//!   outer-product and sieve-mask hot spots, called by L2.
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT so the Rust
//! hot path can offload chunk products; Python never runs at request
//! time (the PJRT engine is behind the `xla` cargo feature; the default
//! build uses a stub and the pure-Rust block kernels).
//!
//! ## The fast path (scheduling and suspension internals)
//!
//! Everything the paper measures reduces to the cost of one suspension,
//! so the two hot layers are engineered accordingly:
//!
//! * **Work-stealing executor** ([`exec`]) — per-worker deques with LIFO
//!   local push/pop and FIFO stealing, a global injector for external
//!   submissions, and park/unpark idle management. The deque is a true
//!   lock-free Chase–Lev ring ([`exec::ChaseLevDeque`]: atomic
//!   `top`/`bottom`, owner push/pop with one release/acquire fence
//!   pair, thieves CAS-ing `top`, pin-based retirement of grown-out
//!   buffers), runtime-selectable against the minimally-locked
//!   baseline ([`exec::DequeKind`]: `Config::deque`, `--deque`,
//!   `SFUT_DEQUE`). Thieves batch: one victim visit moves up to half
//!   the victim's run into the thief's own deque
//!   (`ExecutorStats::{steals_batched, jobs_migrated}`, the
//!   `jobs_migrated_per_steal` gauge). Managed blocking (compensation
//!   threads) is preserved, so `Fut::force` stays deadlock-free even at
//!   par(1). The old single-`Mutex` queue survives as
//!   `Scheduler::GlobalQueue`, the measured baseline; `cargo bench
//!   --bench ablation_overhead` A/Bs all three variants in one run and
//!   records labeled `deque=chase_lev` / `deque=locked` datapoints in
//!   `BENCH_executor.json`, which `sfut check-bench` gates comparing
//!   like-labeled points only.
//! * **Lock-free future cells** ([`susp`]) — `Fut<T>` is an atomic state
//!   machine (EMPTY → RUNNING → READY/PANICKED): `is_ready`, `force`,
//!   and callback registration on a completed cell are single Acquire
//!   loads; the callback mutex is only touched while still pending.
//!   `map`/`flat_map` over an already-complete cell run inline on the
//!   caller (depth-bounded, trampolining onto worker stacks every 8
//!   frames so heavy chunk chains still fan out across workers).
//! * **Adaptive chunking** ([`stream::ChunkSizer`]) — §7's chunk size is
//!   picked from a *measured* per-element cost and the executor's
//!   parallelism (`poly::chunked_times_adaptive`,
//!   `sieve::chunked_primes_adaptive`) instead of a fixed constant. It
//!   is the coordinator default ([`config::ChunkPolicy`]); the probe
//!   cost is memoized per (shard, workload) in a [`stream::CostCache`]
//!   so repeated jobs skip it.
//! * **Sharded coordinator behind a staged ingress**
//!   ([`coordinator::ShardSet`], `coordinator::ingress`) — every job
//!   takes the same four-stage path: **admit** into a bounded MPMC
//!   queue ([`Pipeline::submit`](coordinator::Pipeline::submit) returns
//!   a [`JobTicket`](coordinator::JobTicket) — a [`susp::Fut`] cell, so
//!   the service layer composes with `and_then`/`bind` exactly like the
//!   paper's stream cells — and `queue_depth`/`admission` =
//!   block | shed | timeout(ms) give explicit backpressure); **route**
//!   via workload-affinity hash with least-loaded fallback onto a
//!   shard's run queue; **execute** on per-shard runner threads drawing
//!   warm `par(k)` pools, with idle shards stealing whole queued jobs
//!   from backed-up ones (cross-shard migration,
//!   `shard.<id>.migrated_in/out`); **report** timing, queue wait, and
//!   migration into the metrics registry and the `JobResult` line.
//!   `cargo bench --bench pipeline_throughput` records jobs/sec +
//!   p50/p95 latency + queue-wait p50/p95 + shed rate at shards
//!   ∈ {1, 2, N} into `BENCH_pipeline.json`, which CI's `bench-gate`
//!   job enforces (>25% throughput regressions fail; p95 latency and
//!   queue-wait growth warns by default and fails under
//!   `sfut check-bench --latency-strict` / `BENCH_GATE_LATENCY_STRICT=1`
//!   — auto-disarmed while the committed baseline's note marks it a
//!   synthetic floor; the `bench-baseline` workflow produces measured
//!   replacements — see `ci/check_bench.sh`).
//! * **Open workload-plugin surface** ([`workload`]) — the coordinator
//!   serves an *open* set of scenarios: a
//!   [`workload::StreamWorkload`] trait (name, param schema, generic
//!   `run` over `E: Eval` via [`workload::EvalBody`], independent
//!   `verify`, backend/cost hooks) registered in a
//!   [`workload::WorkloadRegistry`] that the router, verifier, serve
//!   protocol, and bench harness all dispatch through *by name* — no
//!   workload enum, no dispatch `match` anywhere in the coordinator.
//!   Requests carry typed params on the wire
//!   (`run stream(big_factor=7,chunked=true) par(2)`), schema-checked
//!   at submit before any queue capacity is taken. The paper's nine
//!   Table-1 scenarios are three plugin families ([`workload::builtin`]:
//!   sieve, stream-multiply, list baseline); `fib` (big-integer
//!   Fibonacci stream) and `msort` (streaming merge sort on
//!   `merge_sorted`) shipped through the public API alone
//!   ([`workload::extra`]) — the existence proof that new scenarios
//!   need zero coordinator edits. `sfut workloads` / the serve
//!   `workloads` verb list every registration with its schema, and the
//!   conformance suite (`rust/tests/workload_registry.rs`) holds every
//!   plugin to Seq-self-verifies / Par(2)-equals-Seq / well-formed err
//!   lines. See `coordinator`'s module docs for the plugin-writing
//!   guide.
//! * **Fault-contained job lifecycle** (`coordinator::ingress`,
//!   [`susp::cancel`]) — runner threads execute plugins under
//!   `catch_unwind`, so a panicking workload costs one job, not a
//!   runner: the panic resolves the ticket as a machine-parseable
//!   `err panicked …` line and the thread keeps serving. Per-job
//!   deadlines (`deadline_ms` wire param / `Config::deadline_ms`) are
//!   enforced by a reaper thread tripping a cooperative
//!   [`susp::CancelToken`] that stream traversals and chunked bodies
//!   poll between elements. Transient failures (panic, timeout) retry
//!   up to `Config::retry_max` times on the next shard with exponential
//!   backoff, and `Config::breaker_threshold` consecutive panics open a
//!   per-workload circuit breaker that rejects further submissions up
//!   front. The full `err` taxonomy and the retry/breaker state machine
//!   are documented in [`coordinator`]'s "Failure semantics" section;
//!   the seeded chaos suite (`rust/tests/chaos_lifecycle.rs`, behind
//!   the `chaos` feature) reconciles injected faults against wire
//!   output and the `jobs.panicked` / `jobs.timed_out` / `jobs.retried`
//!   counters exactly.
//! * **Framed event-loop ingress** ([`coordinator::frame`],
//!   `coordinator::reactor`) — the TCP front-end is no longer
//!   thread-per-session: a pool of reactor threads (`Config::reactors`,
//!   0 = auto from cores) owns the framed connections, each session
//!   pinned to one reactor for its lifetime, speaking a length-prefixed
//!   binary protocol (magic `SFUT` + version preamble; u32 LE length,
//!   u8 kind, payload) with pipelined multi-job batches per read.
//!   Readiness comes through a swappable `Poller` backend —
//!   `Config::poller` = poll | epoll | auto (`--poller`, `SFUT_POLLER`)
//!   — and accepts fan out via `SO_REUSEPORT` listener groups on linux
//!   (in-process round-robin handoff elsewhere). Job completion wakes
//!   the owning reactor through the ticket's [`susp::Fut`]
//!   `on_complete` callback and a per-reactor self-pipe — the paper's
//!   promise path, never a thread parked per waiter. Backpressure is
//!   end-to-end: a non-draining client stops being read
//!   (`wire.read_paused`) and submits flow through the nonblocking
//!   admission path, answering the same `err admission=…` taxonomy as
//!   text. The text protocol survives as compat mode and A/B baseline
//!   (`Config::wire` = framed | text, `--wire`, `SFUT_WIRE`;
//!   per-listener via [`coordinator::TcpServer::start_wire`]), and
//!   `cargo bench --bench ingress_wire` sweeps BOTH modes — framed
//!   crossed with (poller × reactor count) — over a connection ladder
//!   into `BENCH_ingress.json`, which CI's ingress gate compares
//!   cell-wise (a current run missing either wire mode, or a framed
//!   poller the baseline has, hard-fails). The frame layout, kind
//!   table, and pool architecture live in [`coordinator`]'s "Wire
//!   protocol" section; the conformance corpus
//!   (`rust/tests/framed_wire.rs`) holds every malformed input to at
//!   most one err frame and a clean close under every poller backend,
//!   and `rust/tests/reactor_pool.rs` pins the fanout, pinning, and
//!   drain invariants.
//!
//! ## Correctness tooling
//!
//! The lock-free core is held to its invariants by three in-tree
//! mechanisms, none of which require external dependencies:
//!
//! * **Deterministic model checking** ([`testkit::model`]) — a vendored
//!   "loom-lite": shim atomics (`ModelAtomicU64`, `ModelAtomicUsize`,
//!   `model_fence`) that compile straight to `std::sync::atomic`
//!   normally, but under `--features model` route every load / store /
//!   CAS / fence through a virtual scheduler that explores thread
//!   interleavings (bounded-preemption DFS plus seeded random
//!   schedules). The Chase–Lev deque (grow-under-steal, wraparound
//!   indices, pin-based buffer retirement) and the `Fut`
//!   EMPTY→RUNNING→READY/PANICKED machine are ported onto the shims and
//!   checked for job loss, duplication, use-after-free, and
//!   exactly-once callback delivery by `cargo test --features model
//!   --test model_check`. A failing schedule prints a replayable seed;
//!   pin it with `SFUT_MODEL_SEED=<seed>` to reproduce the exact
//!   interleaving byte-for-byte.
//! * **Static invariant lint** ([`lint`], `sfut lint`) — a
//!   line-oriented pass over the crate's own sources enforcing that
//!   every `unsafe` carries a `SAFETY:` justification, metric names
//!   match the documented taxonomy, `Config` keys stay documented in
//!   `--help` and the coordinator docs, and integration tests parse
//!   `err` lines through `testkit::wire` instead of ad-hoc string
//!   matching. CI runs it as a blocking step; deliberate exceptions go
//!   in `ci/lint_allowlist.txt`.
//! * **Sanitizer CI** — nightly Miri over the deque and future unit
//!   suites (`cargo miri test --lib -- exec::deque susp::future`) and
//!   ThreadSanitizer (`RUSTFLAGS=-Zsanitizer=thread`) over the
//!   cross-thread deque stress test under both `SFUT_DEQUE` kinds, as
//!   named steps in `.github/workflows/ci.yml`.
//!
//! The crate-wide `#![deny(unsafe_op_in_unsafe_fn)]` below means every
//! unsafe operation — even inside an `unsafe fn` — sits in an explicit
//! `unsafe {}` block with its own `// SAFETY:` comment for the lint to
//! check.

#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench_harness;
pub mod bigint;
pub mod config;
pub mod coordinator;
pub mod exec;
pub mod lint;
pub mod logging;
pub mod metrics;
pub mod par;
pub mod poly;
pub mod rational;
pub mod runtime;
pub mod sieve;
pub mod stream;
pub mod susp;
pub mod testkit;
pub mod workload;

/// The most common imports, bundled.
pub mod prelude {
    pub use crate::config::{Config, Mode};
    pub use crate::exec::Executor;
    pub use crate::stream::Stream;
    pub use crate::susp::{Eval, FutureEval, LazyEval, StrictEval, Susp};
    pub use crate::workload::{Params, StreamWorkload, WorkloadCtx, WorkloadRegistry};
}

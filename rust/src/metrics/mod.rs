//! Lightweight metrics: counters, gauges, timers and a registry that the
//! coordinator and benchmark harness use to report per-stage statistics.
//!
//! Everything is lock-free on the hot path (atomics); rendering snapshots
//! takes the registry lock only.

mod histogram;
mod registry;

pub use histogram::Histogram;
pub use registry::{MetricsRegistry, Snapshot};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Arc<Self> {
        Arc::new(Counter(AtomicU64::new(0)))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (e.g. queue depth).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Arc<Self> {
        Arc::new(Gauge(AtomicU64::new(0)))
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Accumulates total nanoseconds and event count; reports mean latency.
#[derive(Debug, Default)]
pub struct Timer {
    nanos: AtomicU64,
    count: AtomicU64,
}

impl Timer {
    pub fn new() -> Arc<Self> {
        Arc::new(Timer::default())
    }

    /// Time a closure.
    pub fn time<R>(&self, f: impl FnOnce() -> R) -> R {
        let start = Instant::now();
        let out = f();
        self.record(start.elapsed());
        out
    }

    pub fn record(&self, d: Duration) {
        self.nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn total(&self) -> Duration {
        Duration::from_nanos(self.nanos.load(Ordering::Relaxed))
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            Duration::ZERO
        } else {
            self.total() / c as u32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn gauge_overwrites() {
        let g = Gauge::new();
        g.set(10);
        g.set(3);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn timer_records() {
        let t = Timer::new();
        let out = t.time(|| {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(out, 42);
        assert_eq!(t.count(), 1);
        assert!(t.total() >= Duration::from_millis(4));
        assert!(t.mean() >= Duration::from_millis(4));
    }

    #[test]
    fn timer_mean_of_zero_events_is_zero() {
        let t = Timer::new();
        assert_eq!(t.mean(), Duration::ZERO);
    }

    #[test]
    fn counter_is_thread_safe() {
        let c = Counter::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 8000);
    }
}

//! Named metric registry + snapshot rendering.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::{Counter, Gauge, Histogram, Timer};

/// Central registry the coordinator publishes metrics through. Cheap to
/// clone (shared).
#[derive(Clone, Default)]
pub struct MetricsRegistry {
    inner: Arc<Mutex<Inner>>,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    timers: BTreeMap<String, Arc<Timer>>,
    histograms: BTreeMap<String, Arc<Histogram>>,
}

/// Point-in-time view of every metric, ready for rendering.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    /// name -> (count, mean)
    pub timers: BTreeMap<String, (u64, Duration)>,
    /// name -> (count, mean, p50, p99, max)
    pub histograms: BTreeMap<String, (u64, Duration, Duration, Duration, Duration)>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut inner = self.inner.lock().unwrap();
        inner.counters.entry(name.to_string()).or_insert_with(Counter::new).clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut inner = self.inner.lock().unwrap();
        inner.gauges.entry(name.to_string()).or_insert_with(Gauge::new).clone()
    }

    pub fn timer(&self, name: &str) -> Arc<Timer> {
        let mut inner = self.inner.lock().unwrap();
        inner.timers.entry(name.to_string()).or_insert_with(Timer::new).clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut inner = self.inner.lock().unwrap();
        inner
            .histograms
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone()
    }

    pub fn snapshot(&self) -> Snapshot {
        let inner = self.inner.lock().unwrap();
        Snapshot {
            counters: inner.counters.iter().map(|(k, v)| (k.clone(), v.get())).collect(),
            gauges: inner.gauges.iter().map(|(k, v)| (k.clone(), v.get())).collect(),
            timers: inner
                .timers
                .iter()
                .map(|(k, v)| (k.clone(), (v.count(), v.mean())))
                .collect(),
            histograms: inner
                .histograms
                .iter()
                .map(|(k, v)| {
                    (
                        k.clone(),
                        (v.count(), v.mean(), v.quantile(0.5), v.quantile(0.99), v.max()),
                    )
                })
                .collect(),
        }
    }
}

impl Snapshot {
    /// Human-readable multi-line report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("counter  {k:<40} {v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("gauge    {k:<40} {v}\n"));
        }
        for (k, (n, mean)) in &self.timers {
            out.push_str(&format!("timer    {k:<40} n={n} mean={mean:?}\n"));
        }
        for (k, (n, mean, p50, p99, max)) in &self.histograms {
            out.push_str(&format!(
                "hist     {k:<40} n={n} mean={mean:?} p50={p50:?} p99={p99:?} max={max:?}\n"
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_name_returns_same_metric() {
        let reg = MetricsRegistry::new();
        reg.counter("a").inc();
        reg.counter("a").inc();
        assert_eq!(reg.counter("a").get(), 2);
    }

    #[test]
    fn snapshot_contains_all_kinds() {
        let reg = MetricsRegistry::new();
        reg.counter("c").add(3);
        reg.gauge("g").set(7);
        reg.timer("t").record(Duration::from_micros(5));
        reg.histogram("h").record(Duration::from_micros(9));
        let snap = reg.snapshot();
        assert_eq!(snap.counters["c"], 3);
        assert_eq!(snap.gauges["g"], 7);
        assert_eq!(snap.timers["t"].0, 1);
        assert_eq!(snap.histograms["h"].0, 1);
        let text = snap.render();
        assert!(text.contains("counter"));
        assert!(text.contains("hist"));
    }

    #[test]
    fn registry_clone_shares_state() {
        let reg = MetricsRegistry::new();
        let reg2 = reg.clone();
        reg.counter("shared").inc();
        assert_eq!(reg2.snapshot().counters["shared"], 1);
    }
}

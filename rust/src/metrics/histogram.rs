//! Log-bucketed latency histogram (HdrHistogram-lite).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Power-of-two-bucketed histogram of nanosecond values. 64 buckets cover
/// 1 ns .. ~584 years; enough resolution for percentile reporting in the
//  bench harness.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    fn bucket_of(nanos: u64) -> usize {
        (64 - nanos.max(1).leading_zeros() as usize) - 1
    }

    pub fn record(&self, d: Duration) {
        let n = d.as_nanos().min(u64::MAX as u128) as u64;
        self.buckets[Self::bucket_of(n)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(n, Ordering::Relaxed);
        self.max.fetch_max(n, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum.load(Ordering::Relaxed) / c)
    }

    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max.load(Ordering::Relaxed))
    }

    /// Upper bound of the bucket containing the q-quantile (0.0..=1.0).
    /// Resolution is one power of two — good enough to tell 1 µs from
    /// 100 µs task grain, which is what the paper's observation 1 needs.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((total as f64) * q).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_nanos(1u64 << (i + 1).min(63));
            }
        }
        self.max()
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count())
            .field("mean", &self.mean())
            .field("p50", &self.quantile(0.5))
            .field("p99", &self.quantile(0.99))
            .field("max", &self.max())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn records_and_buckets() {
        let h = Histogram::new();
        for _ in 0..100 {
            h.record(Duration::from_nanos(1000)); // bucket ~2^9
        }
        h.record(Duration::from_millis(10)); // outlier
        assert_eq!(h.count(), 101);
        // p50 should be near 1 µs (within its power-of-two bucket).
        assert!(h.quantile(0.5) <= Duration::from_nanos(2048));
        // p100 catches the outlier.
        assert!(h.quantile(1.0) >= Duration::from_millis(8));
        assert!(h.max() >= Duration::from_millis(10));
    }

    #[test]
    fn bucket_of_boundaries() {
        assert_eq!(Histogram::bucket_of(1), 0);
        assert_eq!(Histogram::bucket_of(2), 1);
        assert_eq!(Histogram::bucket_of(3), 1);
        assert_eq!(Histogram::bucket_of(4), 2);
        assert_eq!(Histogram::bucket_of(u64::MAX), 63);
        // zero clamps to bucket 0 rather than panicking
        assert_eq!(Histogram::bucket_of(0), 0);
    }
}

//! The injector queue shared by all workers.
//!
//! A `Mutex<VecDeque>` + `Condvar` is deliberately the *baseline*
//! implementation; `benches/ablation_overhead.rs` (section 6) measures it
//! against the per-worker stealable deques (both the Chase–Lev ring and
//! the locked variant — see `exec::deque`) and records the labeled gaps
//! in `BENCH_executor.json`. At the paper's task granularity (hundreds of
//! microseconds and up for `stream_big`) the single lock is nowhere near
//! the bottleneck; at `primes` granularity it is part of the overhead the
//! paper itself observes (observation 1 in §7).
//!
//! Note: the worker pool now parks on its own condvar and only calls
//! `push`/`try_pop`; the blocking [`JobQueue::pop`] (and its internal
//! `Condvar`) is retained as standalone blocking-queue API, exercised by
//! this module's tests.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::Job;

/// FIFO job queue with blocking pop and shutdown support.
pub struct JobQueue {
    inner: Mutex<QueueState>,
    available: Condvar,
    /// Mirror of `QueueState::shutdown`, readable without the lock — the
    /// work-stealing spawn fast path polls it on every local push.
    shutdown: AtomicBool,
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

/// Result of a blocking pop.
pub enum Popped {
    /// A job to run.
    Job(Job),
    /// The queue was shut down and drained.
    Shutdown,
    /// Timed out waiting (used by compensation workers to retire).
    TimedOut,
}

impl JobQueue {
    pub fn new() -> Self {
        JobQueue {
            inner: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        }
    }

    /// Push a job; wakes one waiting worker. Returns `false` when the
    /// queue is already shut down (the job is dropped).
    pub fn push(&self, job: Job) -> bool {
        let mut st = self.inner.lock().unwrap();
        if st.shutdown {
            return false;
        }
        st.jobs.push_back(job);
        drop(st);
        self.available.notify_one();
        true
    }

    /// Blocking pop with an optional timeout.
    pub fn pop(&self, timeout: Option<Duration>) -> Popped {
        let mut st = self.inner.lock().unwrap();
        loop {
            if let Some(job) = st.jobs.pop_front() {
                return Popped::Job(job);
            }
            if st.shutdown {
                return Popped::Shutdown;
            }
            match timeout {
                Some(t) => {
                    let (g, res) = self.available.wait_timeout(st, t).unwrap();
                    st = g;
                    if res.timed_out() && st.jobs.is_empty() {
                        return if st.shutdown { Popped::Shutdown } else { Popped::TimedOut };
                    }
                }
                None => {
                    st = self.available.wait(st).unwrap();
                }
            }
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<Job> {
        self.inner.lock().unwrap().jobs.pop_front()
    }

    /// Number of queued (not yet started) jobs.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Mark the queue shut down; wakes all waiting workers. Queued jobs
    /// still drain (workers exit once empty + shutdown).
    pub fn shutdown(&self) {
        {
            let mut st = self.inner.lock().unwrap();
            st.shutdown = true;
            // Set the mirror while holding the lock so the lock-free view
            // can never lag a locked observation.
            self.shutdown.store(true, Ordering::SeqCst);
        }
        self.available.notify_all();
    }

    /// Lock-free shutdown check (hot path: every spawn).
    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

impl Default for JobQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    #[test]
    fn push_pop_fifo() {
        let q = JobQueue::new();
        let hit = Arc::new(AtomicBool::new(false));
        let hit2 = hit.clone();
        assert!(q.push(Box::new(move || hit2.store(true, Ordering::SeqCst))));
        match q.pop(None) {
            Popped::Job(j) => j(),
            _ => panic!("expected job"),
        }
        assert!(hit.load(Ordering::SeqCst));
        assert!(q.is_empty());
    }

    #[test]
    fn shutdown_rejects_push_and_unblocks_pop() {
        let q = Arc::new(JobQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || matches!(q2.pop(None), Popped::Shutdown));
        std::thread::sleep(Duration::from_millis(20));
        q.shutdown();
        assert!(h.join().unwrap());
        assert!(!q.push(Box::new(|| {})));
    }

    #[test]
    fn timed_pop_times_out() {
        let q = JobQueue::new();
        match q.pop(Some(Duration::from_millis(10))) {
            Popped::TimedOut => {}
            _ => panic!("expected timeout"),
        }
    }

    #[test]
    fn drains_queued_jobs_after_shutdown() {
        let q = JobQueue::new();
        q.push(Box::new(|| {}));
        q.push(Box::new(|| {}));
        q.shutdown();
        assert!(matches!(q.pop(None), Popped::Job(_)));
        assert!(matches!(q.pop(None), Popped::Job(_)));
        assert!(matches!(q.pop(None), Popped::Shutdown));
    }
}

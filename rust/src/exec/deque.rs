//! Per-worker stealable deque — lock-free Chase–Lev ring by default,
//! the old minimally-locked `Mutex<VecDeque>` kept runtime-selectable.
//!
//! The owner pushes and pops at the bottom (LIFO — the hot path of a
//! fork/join-style workload keeps the most recently spawned, cache-warm
//! task on top); thieves take from the top (FIFO — they get the
//! *oldest* task, which for recursive spawns is the largest remaining
//! subtree, minimizing steal frequency).
//!
//! ## [`ChaseLevDeque`] (default, [`DequeKind::ChaseLev`])
//!
//! A true lock-free Chase–Lev deque: a growable circular [`Buffer`] of
//! jobs indexed by two monotonically increasing (wrapping `u64`) atomic
//! indices, `top` and `bottom`. The owner's `push`/`pop` touch only the
//! bottom end and synchronize with thieves through a single
//! release/acquire fence pair plus one SeqCst fence in `pop`; thieves
//! claim the top element by CAS-ing `top` forward. Fence placement
//! follows Le, Pop, Cohen & Nardelli, *Correct and Efficient
//! Work-Stealing for Weak Memory Models* (PPoPP '13) — the verified
//! C11 formulation of the original Chase–Lev algorithm.
//!
//! **Growth** allocates a doubled buffer, bit-copies the live index
//! range, and publishes the new pointer with a SeqCst store. A replaced
//! buffer cannot be freed immediately — a concurrent thief may have
//! loaded the old pointer and still be reading a slot — so retirement
//! is epoch-style: thieves *pin* the deque (one atomic increment)
//! around the window in which they dereference the buffer pointer, and
//! the owner moves replaced buffers onto a limbo list that is freed
//! only when the pin count reads zero (the SeqCst ordering between the
//! publish store, the pin RMW, and the pin read guarantees any thief
//! pinned after that read observes the *new* buffer). Limbo memory is
//! bounded: buffer sizes double, so everything parked there together is
//! smaller than the live buffer.
//!
//! **Steal-half batching** ([`WorkerDeque::steal_batch_and_pop`]): a
//! thief takes up to ⌈len/2⌉ jobs (capped at [`MAX_STEAL_BATCH`]) in
//! one victim visit — the first is returned to run immediately, the
//! rest land in the thief's own deque where they are locally poppable
//! and stealable by third parties. Each job still transfers through the
//! full single-steal fence-and-CAS protocol: with a LIFO owner popping
//! the bottom *without* synchronization (except on the last element), a
//! single multi-element CAS on `top` could claim a range the owner has
//! meanwhile partially consumed, duplicating jobs. Per-element CAS
//! makes every transfer individually linearizable; the batching win is
//! amortizing the victim scan and the thief's cache misses, not the
//! CAS.
//!
//! ## [`LockedDeque`] ([`DequeKind::Locked`])
//!
//! The previous implementation — one short-critical-section
//! `Mutex<VecDeque>` per worker — kept compiled and runtime-selectable
//! (`Config::deque`, `SFUT_DEQUE`) as the measured A/B baseline for
//! `BENCH_executor.json`: an uncontended mutex is a pair of atomic
//! RMWs, so the delta against the CAS ring isolates exactly what the
//! lock-free structure buys at this crate's task granularity.
//!
//! Ownership contract (both kinds): `push`, `pop`, and `drain` are
//! owner-only — at most one thread at a time (with proper
//! happens-before on handoff, e.g. a thread join) may call them, and
//! `steal_batch_and_pop` requires the caller to be the owner of the
//! *destination* deque. Because the Chase–Lev owner end is
//! intentionally unsynchronized, these methods are `unsafe fn`s: the
//! contract is a memory-safety requirement, not a convention (two
//! concurrent pushes race on a slot and can lose or tear a job).
//! `steal`, `len`, and `is_empty` are safe from any thread. The
//! executor upholds the contract by construction: a deque is created
//! inside `worker_loop` and only its worker pushes and pops it.

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::mem::MaybeUninit;
use std::sync::atomic::{fence, AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use super::Job;

/// Most jobs one batch steal moves (the first popped plus the rest
/// landed in the thief's deque). Bounds the time a thief spends inside
/// one victim visit and leaves work for other thieves.
pub const MAX_STEAL_BATCH: usize = 16;

/// Which per-worker deque implementation an executor runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DequeKind {
    /// Lock-free Chase–Lev ring deque (default).
    #[default]
    ChaseLev,
    /// Minimally-locked `Mutex<VecDeque>` — the measured A/B baseline.
    Locked,
}

impl DequeKind {
    pub const ALL: [DequeKind; 2] = [DequeKind::ChaseLev, DequeKind::Locked];

    /// The label used in config values, `SFUT_DEQUE`, and
    /// `BENCH_executor.json` datapoints.
    pub fn label(self) -> &'static str {
        match self {
            DequeKind::ChaseLev => "chase_lev",
            DequeKind::Locked => "locked",
        }
    }

    /// Read the `SFUT_DEQUE` environment override, if set.
    ///
    /// Panics on an *invalid* value rather than falling back: this env
    /// var is how CI pins the whole test suite to one implementation —
    /// a typo silently selecting the default would green-light a named
    /// "locked" step that never ran the locked deque.
    pub fn from_env() -> Option<DequeKind> {
        let v = std::env::var("SFUT_DEQUE").ok()?;
        match v.parse() {
            Ok(kind) => Some(kind),
            Err(e) => panic!("invalid SFUT_DEQUE: {e}"),
        }
    }

    /// The process-wide default: `SFUT_DEQUE` when set (how CI runs the
    /// same test suite under both implementations), else Chase–Lev.
    pub fn default_kind() -> DequeKind {
        Self::from_env().unwrap_or(DequeKind::ChaseLev)
    }
}

impl std::str::FromStr for DequeKind {
    type Err = String;

    fn from_str(s: &str) -> Result<DequeKind, String> {
        match s.trim() {
            "chase_lev" | "chase-lev" | "chaselev" => Ok(DequeKind::ChaseLev),
            "locked" | "mutex" => Ok(DequeKind::Locked),
            other => Err(format!("unknown deque kind: {other} (want chase_lev | locked)")),
        }
    }
}

impl std::fmt::Display for DequeKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// A single worker's job deque: owner end = bottom (LIFO), thief end =
/// top (FIFO). See the module docs for the per-kind designs and the
/// owner-only contract on `push`/`pop`/`drain`.
pub enum WorkerDeque {
    Locked(LockedDeque),
    ChaseLev(ChaseLevDeque),
}

impl WorkerDeque {
    /// A deque of the process-default kind ([`DequeKind::default_kind`],
    /// i.e. `SFUT_DEQUE` or Chase–Lev).
    pub fn new() -> Self {
        Self::with_kind(DequeKind::default_kind())
    }

    pub fn with_kind(kind: DequeKind) -> Self {
        match kind {
            DequeKind::ChaseLev => WorkerDeque::ChaseLev(ChaseLevDeque::new()),
            DequeKind::Locked => WorkerDeque::Locked(LockedDeque::new()),
        }
    }

    pub fn kind(&self) -> DequeKind {
        match self {
            WorkerDeque::Locked(_) => DequeKind::Locked,
            WorkerDeque::ChaseLev(_) => DequeKind::ChaseLev,
        }
    }

    /// Owner push (bottom).
    ///
    /// # Safety
    ///
    /// Owner-only: at most one thread at a time may call the owner-end
    /// methods (`push`/`pop`/`drain`) on this deque, with proper
    /// happens-before ordering on any ownership handoff. See the
    /// module docs.
    pub unsafe fn push(&self, job: Job) {
        match self {
            WorkerDeque::Locked(d) => d.push(job),
            // SAFETY: forwards our own owner-only contract (above) to
            // the Chase–Lev owner end.
            WorkerDeque::ChaseLev(d) => unsafe { d.push(job) },
        }
    }

    /// Owner pop (bottom, LIFO).
    ///
    /// # Safety
    ///
    /// Owner-only; same contract as [`WorkerDeque::push`].
    pub unsafe fn pop(&self) -> Option<Job> {
        match self {
            WorkerDeque::Locked(d) => d.pop(),
            // SAFETY: forwards our own owner-only contract (above) to
            // the Chase–Lev owner end.
            WorkerDeque::ChaseLev(d) => unsafe { d.pop() },
        }
    }

    /// Thief pop (top, FIFO). Any thread. `None` means empty *or* lost
    /// a race — callers treat both as "move on".
    pub fn steal(&self) -> Option<Job> {
        match self {
            WorkerDeque::Locked(d) => d.steal(),
            WorkerDeque::ChaseLev(d) => d.steal(),
        }
    }

    /// Steal up to ⌈len/2⌉ jobs (capped at [`MAX_STEAL_BATCH`]): the
    /// first is returned to run now, the rest are pushed into `dest` —
    /// the calling thief's *own* deque. Returns the first job and how
    /// many extra jobs were moved into `dest`. The victim keeps the
    /// newer half of its run in order (its LIFO discipline is
    /// undisturbed). `None` means empty or contended.
    ///
    /// # Safety
    ///
    /// The caller must be the owner of `dest` (stolen jobs are pushed
    /// onto its owner end); stealing from `self` is safe from any
    /// thread.
    pub unsafe fn steal_batch_and_pop(&self, dest: &WorkerDeque) -> Option<(Job, usize)> {
        match self {
            WorkerDeque::Locked(d) => {
                let (first, rest) = d.steal_half(MAX_STEAL_BATCH)?;
                let moved = rest.len();
                for job in rest {
                    // SAFETY: the caller owns `dest` (our contract
                    // above), so pushing onto its owner end is theirs
                    // to do.
                    unsafe { dest.push(job) };
                }
                Some((first, moved))
            }
            WorkerDeque::ChaseLev(d) => {
                // Size the batch from one snapshot, then transfer each
                // job through the full single-steal protocol (see the
                // module docs for why one big CAS would be unsound
                // against a LIFO owner).
                let goal = d.len().div_ceil(2).min(MAX_STEAL_BATCH);
                let mut first = None;
                let mut moved = 0usize;
                for _ in 0..goal.max(1) {
                    match d.steal() {
                        Some(job) if first.is_none() => first = Some(job),
                        Some(job) => {
                            // SAFETY: the caller owns `dest` (our
                            // contract above).
                            unsafe { dest.push(job) };
                            moved += 1;
                        }
                        // Empty or lost a race: stop with what we have.
                        None => break,
                    }
                }
                first.map(|job| (job, moved))
            }
        }
    }

    /// Queued jobs (instantaneous; for stats and idle checks).
    pub fn len(&self) -> usize {
        match self {
            WorkerDeque::Locked(d) => d.len(),
            WorkerDeque::ChaseLev(d) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Take everything (worker exit path; order unspecified).
    ///
    /// # Safety
    ///
    /// Owner-only; same contract as [`WorkerDeque::push`].
    pub unsafe fn drain(&self) -> Vec<Job> {
        match self {
            WorkerDeque::Locked(d) => d.drain(),
            // SAFETY: forwards our own owner-only contract (above) to
            // the Chase–Lev owner end.
            WorkerDeque::ChaseLev(d) => unsafe { d.drain() },
        }
    }
}

impl Default for WorkerDeque {
    fn default() -> Self {
        Self::new()
    }
}

impl From<ChaseLevDeque> for WorkerDeque {
    fn from(d: ChaseLevDeque) -> Self {
        WorkerDeque::ChaseLev(d)
    }
}

impl From<LockedDeque> for WorkerDeque {
    fn from(d: LockedDeque) -> Self {
        WorkerDeque::Locked(d)
    }
}

// ---------------------------------------------------------------------
// Locked baseline
// ---------------------------------------------------------------------

/// The minimally-locked deque: one short-critical-section
/// `Mutex<VecDeque>`. Kept as the runtime-selectable A/B baseline.
pub struct LockedDeque {
    jobs: Mutex<VecDeque<Job>>,
}

impl LockedDeque {
    pub fn new() -> Self {
        LockedDeque { jobs: Mutex::new(VecDeque::new()) }
    }

    pub fn push(&self, job: Job) {
        self.jobs.lock().unwrap().push_back(job);
    }

    pub fn pop(&self) -> Option<Job> {
        self.jobs.lock().unwrap().pop_back()
    }

    pub fn steal(&self) -> Option<Job> {
        self.jobs.lock().unwrap().pop_front()
    }

    /// Take the oldest job plus up to ⌈len/2⌉ − 1 more (bounded by
    /// `max`), front-first, leaving the victim's newer half in order.
    /// The batch is collected under the victim's lock and returned —
    /// the caller pushes it into its own deque *after* this lock is
    /// released (two thieves stealing from each other must never hold
    /// both locks at once).
    pub fn steal_half(&self, max: usize) -> Option<(Job, Vec<Job>)> {
        let mut q = self.jobs.lock().unwrap();
        let len = q.len();
        if len == 0 {
            return None;
        }
        let take = len.div_ceil(2).min(max.max(1));
        let first = q.pop_front().expect("len checked above");
        let rest: Vec<Job> = q.drain(..take - 1).collect();
        Some((first, rest))
    }

    pub fn len(&self) -> usize {
        self.jobs.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn drain(&self) -> Vec<Job> {
        self.jobs.lock().unwrap().drain(..).collect()
    }
}

impl Default for LockedDeque {
    fn default() -> Self {
        Self::new()
    }
}

// ---------------------------------------------------------------------
// Chase–Lev ring deque
// ---------------------------------------------------------------------

/// Initial ring capacity (power of two; doubles on overflow). Small
/// enough that the grow path is exercised by ordinary workloads.
const MIN_BUFFER_CAP: usize = 16;

/// The growable circular job buffer. Slots are `MaybeUninit` because a
/// slot's bytes may be read racily by a thief whose claiming CAS then
/// fails — the read value is discarded without being treated as a live
/// `Job` (a `MaybeUninit` is never dropped).
struct Buffer {
    /// `capacity - 1`; capacity is a power of two, so absolute indices
    /// map to slots by masking (this is what makes wrapping `u64`
    /// indices safe: consecutive indices stay consecutive mod capacity
    /// even across the `u64::MAX` → `0` boundary).
    mask: u64,
    slots: Box<[UnsafeCell<MaybeUninit<Job>>]>,
}

impl Buffer {
    fn alloc(cap: usize) -> *mut Buffer {
        debug_assert!(cap.is_power_of_two());
        let slots: Box<[UnsafeCell<MaybeUninit<Job>>]> =
            (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
        Box::into_raw(Box::new(Buffer { mask: cap as u64 - 1, slots }))
    }

    fn capacity(&self) -> u64 {
        self.mask + 1
    }

    /// Write a slot.
    ///
    /// # Safety
    ///
    /// Caller guarantees the slot is dead (outside the live
    /// `[top, bottom)` window) and that it is the owner.
    unsafe fn write(&self, index: u64, job: MaybeUninit<Job>) {
        // SAFETY: the slot is dead (caller contract), so no other thread
        // interprets these bytes while we overwrite them.
        unsafe { *self.slots[(index & self.mask) as usize].get() = job };
    }

    /// Read a slot's bytes.
    ///
    /// # Safety
    ///
    /// May race a writer; the caller must only `assume_init` the result
    /// after winning the claiming CAS.
    unsafe fn read(&self, index: u64) -> MaybeUninit<Job> {
        // SAFETY: reading MaybeUninit bytes is always defined; the
        // caller contract defers interpretation until the CAS is won.
        unsafe { std::ptr::read(self.slots[(index & self.mask) as usize].get()) }
    }
}

/// Lock-free Chase–Lev work-stealing deque (see the module docs).
///
/// Indices are wrapping `u64`s: lengths are computed as
/// `bottom.wrapping_sub(top) as i64`, which is exact for any live
/// window shorter than 2⁶³ jobs. [`ChaseLevDeque::with_start_index`]
/// lets tests start both indices at an arbitrary point (e.g. just
/// below `u64::MAX`) to drive the wraparound path.
pub struct ChaseLevDeque {
    /// Thief end. Only ever advances (wrapping); claimed by CAS.
    top: AtomicU64,
    /// Owner end. Owner-written; thieves read it with Acquire.
    bottom: AtomicU64,
    /// Current ring. Replaced (owner-only) on growth with a SeqCst
    /// store; thieves dereference it only while pinned.
    buffer: AtomicPtr<Buffer>,
    /// Thieves currently inside a buffer-dereference window.
    pins: AtomicUsize,
    /// Replaced buffers awaiting quiescence (`pins == 0`).
    limbo: Mutex<Vec<*mut Buffer>>,
}

// SAFETY: the raw buffer pointers are managed by the pin/limbo protocol
// described in the module docs — slots transfer ownership of `Job`s
// (which are `Send`) across threads only through the top CAS or the
// owner's bottom protocol, and a buffer is freed only after it is
// unreachable (replaced, and pin count observed zero under the SeqCst
// ordering argument in `retire`).
unsafe impl Send for ChaseLevDeque {}
unsafe impl Sync for ChaseLevDeque {}

/// RAII pin: while one of these lives, no buffer the thief may have
/// loaded can be freed.
struct Pin<'a> {
    deque: &'a ChaseLevDeque,
}

impl Drop for Pin<'_> {
    fn drop(&mut self) {
        self.deque.pins.fetch_sub(1, Ordering::SeqCst);
    }
}

impl ChaseLevDeque {
    pub fn new() -> Self {
        Self::with_start_index(0)
    }

    /// Test hook: start both indices at `start`, so wraparound across
    /// the `u64` boundary is reachable in bounded test time. Production
    /// code uses [`ChaseLevDeque::new`] (start 0); at one job per
    /// nanosecond the indices would take ~584 years to wrap, but the
    /// arithmetic is wrapping throughout so correctness never depends
    /// on that.
    pub fn with_start_index(start: u64) -> Self {
        ChaseLevDeque {
            top: AtomicU64::new(start),
            bottom: AtomicU64::new(start),
            buffer: AtomicPtr::new(Buffer::alloc(MIN_BUFFER_CAP)),
            pins: AtomicUsize::new(0),
            limbo: Mutex::new(Vec::new()),
        }
    }

    fn pin(&self) -> Pin<'_> {
        self.pins.fetch_add(1, Ordering::SeqCst);
        Pin { deque: self }
    }

    /// Owner push (bottom).
    ///
    /// # Safety
    ///
    /// Owner-only: at most one thread at a time may call
    /// `push`/`pop`/`drain` on this deque (with happens-before
    /// ordering on any ownership handoff). The owner end is
    /// deliberately unsynchronized — concurrent owner calls race on
    /// `bottom` and the slot bytes.
    pub unsafe fn push(&self, job: Job) {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        let mut buf = self.buffer.load(Ordering::Relaxed);
        // SAFETY: the owner's buffer pointer is always live (only the
        // owner itself replaces it, in `grow`).
        if b.wrapping_sub(t) >= unsafe { (*buf).capacity() } {
            self.grow(t, b);
            buf = self.buffer.load(Ordering::Relaxed);
        }
        // SAFETY: owner-only (our contract above) and slot `b` is
        // outside the live window until the bottom store below.
        unsafe { (*buf).write(b, MaybeUninit::new(job)) };
        // Publish the slot before the index: a thief that observes the
        // new bottom (Acquire) must observe the written job.
        fence(Ordering::Release);
        self.bottom.store(b.wrapping_add(1), Ordering::Relaxed);
    }

    /// Owner pop (bottom, LIFO).
    ///
    /// # Safety
    ///
    /// Owner-only; same contract as [`ChaseLevDeque::push`].
    pub unsafe fn pop(&self) -> Option<Job> {
        let b = self.bottom.load(Ordering::Relaxed).wrapping_sub(1);
        let buf = self.buffer.load(Ordering::Relaxed);
        self.bottom.store(b, Ordering::Relaxed);
        // Order the bottom decrement against thieves' top CAS: either a
        // concurrent thief sees the reduced bottom and aborts, or we
        // see its advanced top below.
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        let len = b.wrapping_sub(t) as i64;
        if len < 0 {
            // Was empty: restore the canonical empty state.
            self.bottom.store(t, Ordering::Relaxed);
            return None;
        }
        // SAFETY: owner's buffer pointer is live; the bytes are only
        // interpreted below once the element is provably ours.
        let job = unsafe { (*buf).read(b) };
        if len > 0 {
            // SAFETY: more than one element — the bottom one is ours
            // without synchronization (thieves are fenced off by the
            // decremented bottom + SeqCst fence above), so the slot
            // holds an initialized Job that no thief can claim.
            return Some(unsafe { job.assume_init() });
        }
        // Exactly one element: race thieves for it on `top`.
        let won = self
            .top
            .compare_exchange(t, t.wrapping_add(1), Ordering::SeqCst, Ordering::Relaxed)
            .is_ok();
        self.bottom.store(t.wrapping_add(1), Ordering::Relaxed);
        if won {
            // SAFETY: the top CAS claimed the last element for us; the
            // slot was initialized by the push that published it.
            Some(unsafe { job.assume_init() })
        } else {
            // A thief claimed it; our read is discarded uninterpreted.
            None
        }
    }

    /// Thief pop (top, FIFO). Any thread. `None` means empty or lost
    /// the claiming race.
    pub fn steal(&self) -> Option<Job> {
        let t = self.top.load(Ordering::Acquire);
        // Order the top load before the bottom load: pairs with the
        // owner's pop fence so a concurrent pop is either seen in
        // `bottom` or fails our CAS.
        fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if (b.wrapping_sub(t) as i64) <= 0 {
            return None;
        }
        // Dereference window: pin so a concurrent grow cannot free the
        // buffer under us.
        let _pin = self.pin();
        let buf = self.buffer.load(Ordering::SeqCst);
        // SAFETY: the pin above keeps this buffer out of limbo
        // reclamation for the whole dereference window (see `retire`);
        // the bytes are interpreted only after the CAS below succeeds.
        let job = unsafe { (*buf).read(t) };
        if self
            .top
            .compare_exchange(t, t.wrapping_add(1), Ordering::SeqCst, Ordering::Relaxed)
            .is_ok()
        {
            // SAFETY: winning the top CAS transfers ownership of slot
            // `t` to us; the push that made it visible (Release fence →
            // Acquire bottom load) initialized it.
            Some(unsafe { job.assume_init() })
        } else {
            // Lost to the owner or another thief: the bytes we read are
            // not ours — drop the MaybeUninit without interpreting it.
            None
        }
    }

    /// Queued jobs (instantaneous snapshot).
    pub fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        (b.wrapping_sub(t) as i64).max(0) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Take everything (owner exit path; LIFO order).
    ///
    /// # Safety
    ///
    /// Owner-only; same contract as [`ChaseLevDeque::push`].
    pub unsafe fn drain(&self) -> Vec<Job> {
        let mut out = Vec::new();
        // SAFETY: forwards our own owner-only contract (above) to pop.
        while let Some(job) = unsafe { self.pop() } {
            out.push(job);
        }
        out
    }

    /// Owner-only: double the ring, copying the live window `[t, b)`.
    /// `t` may be stale (thieves advance top concurrently) — copying a
    /// few already-claimed slots is harmless, they are bit-copies no
    /// one will read.
    fn grow(&self, t: u64, b: u64) {
        let old = self.buffer.load(Ordering::Relaxed);
        // SAFETY: grow is owner-only, so `old` is the live buffer.
        let new_cap = (unsafe { (*old).capacity() } as usize) * 2;
        let new = Buffer::alloc(new_cap);
        let mut i = t;
        while i != b {
            // SAFETY: `old` stays live until `retire` below; `new` is
            // private to us until the SeqCst publish; reads are raw
            // bit-copies never interpreted here (stale-`t` slots are
            // copied but unreachable).
            unsafe { (*new).write(i, (*old).read(i)) };
            i = i.wrapping_add(1);
        }
        self.buffer.store(new, Ordering::SeqCst);
        self.retire(old);
    }

    /// Park a replaced buffer; free the limbo list if no thief is
    /// pinned. SeqCst argument: the new buffer pointer was published
    /// (SeqCst store) before this pin read. A pin RMW not observed here
    /// is later in the SeqCst total order, so that thief's subsequent
    /// buffer load (also SeqCst) returns the new pointer — it can never
    /// acquire a reference to anything in limbo.
    fn retire(&self, old: *mut Buffer) {
        let mut limbo = self.limbo.lock().unwrap();
        limbo.push(old);
        if self.pins.load(Ordering::SeqCst) == 0 {
            for p in limbo.drain(..) {
                // SAFETY: every limbo pointer came from Buffer::alloc
                // (Box::into_raw) and was unpublished before parking;
                // pins == 0 under the SeqCst argument above means no
                // thief can still hold a reference.
                unsafe { drop(Box::from_raw(p)) };
            }
        }
    }
}

impl Default for ChaseLevDeque {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for ChaseLevDeque {
    fn drop(&mut self) {
        // SAFETY: &mut self — no concurrent owner or thieves. Drop
        // queued jobs, then free the live buffer and anything still in
        // limbo.
        while unsafe { self.pop() }.is_some() {}
        let buf = *self.buffer.get_mut();
        // SAFETY: &mut self — the live buffer pointer came from
        // Buffer::alloc and nothing can still reference it.
        unsafe { drop(Box::from_raw(buf)) };
        for p in self.limbo.get_mut().unwrap().drain(..) {
            // SAFETY: likewise for parked buffers — no thief exists.
            unsafe { drop(Box::from_raw(p)) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn job(order: &Arc<Mutex<Vec<u32>>>, tag: u32) -> Job {
        let order = Arc::clone(order);
        Box::new(move || order.lock().unwrap().push(tag))
    }

    #[test]
    fn kind_labels_parse_and_roundtrip() {
        for kind in DequeKind::ALL {
            assert_eq!(kind.label().parse::<DequeKind>().unwrap(), kind);
        }
        assert_eq!("chase-lev".parse::<DequeKind>().unwrap(), DequeKind::ChaseLev);
        assert_eq!("mutex".parse::<DequeKind>().unwrap(), DequeKind::Locked);
        assert!("spinlock".parse::<DequeKind>().is_err());
        assert_eq!(WorkerDeque::with_kind(DequeKind::Locked).kind(), DequeKind::Locked);
        assert_eq!(
            WorkerDeque::with_kind(DequeKind::ChaseLev).kind(),
            DequeKind::ChaseLev
        );
        assert_eq!(WorkerDeque::new().kind(), DequeKind::default_kind());
    }

    #[test]
    fn owner_is_lifo_thief_is_fifo() {
        for kind in DequeKind::ALL {
            let d = WorkerDeque::with_kind(kind);
            let order = Arc::new(Mutex::new(Vec::new()));
            for tag in 0..4 {
                unsafe { d.push(job(&order, tag)) };
            }
            // Thief sees the oldest job…
            d.steal().unwrap()();
            // …the owner the newest.
            unsafe { d.pop() }.unwrap()();
            assert_eq!(*order.lock().unwrap(), vec![0, 3], "kind={kind:?}");
            assert_eq!(d.len(), 2);
        }
    }

    #[test]
    fn drain_empties() {
        for kind in DequeKind::ALL {
            let d = WorkerDeque::with_kind(kind);
            let n = Arc::new(AtomicUsize::new(0));
            for _ in 0..5 {
                let n = n.clone();
                unsafe {
                    d.push(Box::new(move || {
                        n.fetch_add(1, Ordering::SeqCst);
                    }))
                };
            }
            let jobs = unsafe { d.drain() };
            assert_eq!(jobs.len(), 5);
            assert!(d.is_empty());
            for j in jobs {
                j();
            }
            assert_eq!(n.load(Ordering::SeqCst), 5, "kind={kind:?}");
        }
    }

    #[test]
    fn steal_half_takes_ceil_half_and_preserves_victim_order() {
        for kind in DequeKind::ALL {
            let victim = WorkerDeque::with_kind(kind);
            let dest = WorkerDeque::with_kind(kind);
            let order = Arc::new(Mutex::new(Vec::new()));
            for tag in 0..10 {
                unsafe { victim.push(job(&order, tag)) };
            }
            let (first, moved) = unsafe { victim.steal_batch_and_pop(&dest) }.expect("non-empty");
            // ⌈10/2⌉ = 5 total: the popped first plus 4 moved.
            assert_eq!(moved, 4, "kind={kind:?}");
            assert_eq!(dest.len(), 4);
            assert_eq!(victim.len(), 5);
            first();
            assert_eq!(order.lock().unwrap().pop(), Some(0), "first = victim's oldest");
            // Victim keeps its newest half in LIFO order.
            for expect in [9, 8, 7, 6, 5] {
                unsafe { victim.pop() }.unwrap()();
                assert_eq!(order.lock().unwrap().pop(), Some(expect), "kind={kind:?}");
            }
            // Dest received the next-oldest run (1..=4), poppable LIFO.
            for expect in [4, 3, 2, 1] {
                unsafe { dest.pop() }.unwrap()();
                assert_eq!(order.lock().unwrap().pop(), Some(expect), "kind={kind:?}");
            }
        }
    }

    #[test]
    fn steal_half_is_capped_at_max_batch() {
        for kind in DequeKind::ALL {
            let victim = WorkerDeque::with_kind(kind);
            let dest = WorkerDeque::with_kind(kind);
            let n = 6 * MAX_STEAL_BATCH;
            for _ in 0..n {
                unsafe { victim.push(Box::new(|| {})) };
            }
            let (_first, moved) = unsafe { victim.steal_batch_and_pop(&dest) }.expect("non-empty");
            assert!(moved < MAX_STEAL_BATCH, "kind={kind:?}, moved={moved}");
            assert_eq!(victim.len(), n - moved - 1);
        }
    }

    #[test]
    fn chase_lev_grows_past_initial_capacity() {
        let d = ChaseLevDeque::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let n = MIN_BUFFER_CAP * 8 + 3;
        for _ in 0..n {
            let hits = hits.clone();
            unsafe {
                d.push(Box::new(move || {
                    hits.fetch_add(1, Ordering::SeqCst);
                }))
            };
        }
        assert_eq!(d.len(), n);
        while let Some(j) = unsafe { d.pop() } {
            j();
        }
        assert_eq!(hits.load(Ordering::SeqCst), n);
        assert!(d.is_empty());
    }

    #[test]
    fn chase_lev_wraps_past_u64_boundary() {
        // Start just below u64::MAX so pushes carry the indices through
        // the wrap; LIFO/FIFO semantics and len must be unaffected.
        let d = ChaseLevDeque::with_start_index(u64::MAX - 2);
        let order = Arc::new(Mutex::new(Vec::new()));
        for tag in 0..8 {
            unsafe { d.push(job(&order, tag)) };
        }
        assert_eq!(d.len(), 8);
        d.steal().unwrap()();
        d.steal().unwrap()();
        unsafe { d.pop() }.unwrap()();
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 7]);
        assert_eq!(d.len(), 5);
        let rest = unsafe { d.drain() };
        assert_eq!(rest.len(), 5);
        assert!(d.is_empty());
        assert!(unsafe { d.pop() }.is_none());
        assert!(d.steal().is_none());
    }

    #[test]
    fn concurrent_owner_and_thieves_lose_nothing() {
        for kind in DequeKind::ALL {
            let d = Arc::new(WorkerDeque::with_kind(kind));
            let done = Arc::new(AtomicUsize::new(0));
            const N: usize = 10_000;
            std::thread::scope(|s| {
                // Owner: push everything, popping occasionally.
                {
                    let d = d.clone();
                    let done = done.clone();
                    s.spawn(move || {
                        for i in 0..N {
                            let done = done.clone();
                            unsafe {
                                d.push(Box::new(move || {
                                    done.fetch_add(1, Ordering::SeqCst);
                                }))
                            };
                            if i % 3 == 0 {
                                if let Some(j) = unsafe { d.pop() } {
                                    j();
                                }
                            }
                        }
                    });
                }
                // Two thieves.
                for _ in 0..2 {
                    let d = d.clone();
                    let done = done.clone();
                    s.spawn(move || {
                        while done.load(Ordering::SeqCst) < N {
                            match d.steal() {
                                Some(j) => j(),
                                None => std::thread::yield_now(),
                            }
                        }
                    });
                }
            });
            assert_eq!(done.load(Ordering::SeqCst), N, "kind={kind:?}");
        }
    }
}

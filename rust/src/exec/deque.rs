//! Per-worker stealable deque.
//!
//! The owner pushes and pops at the back (LIFO — the hot path of a
//! fork/join-style workload keeps the most recently spawned, cache-warm
//! task on top); thieves take from the front (FIFO — they get the
//! *oldest* task, which for recursive spawns is the largest remaining
//! subtree, minimizing steal frequency). This is the classic Chase–Lev
//! discipline.
//!
//! The implementation is minimally-locked rather than lock-free: one
//! short-critical-section `Mutex<VecDeque>` per worker. An uncontended
//! `Mutex` lock/unlock is a pair of atomic RMWs — within noise of a
//! CAS-based deque at this repo's task granularity — and the contended
//! case (an owner racing a thief) is rare by construction because
//! thieves only appear when their own deque and the injector are both
//! empty. What the design removes is the *global* lock: under the old
//! single `Mutex<VecDeque>` + `Condvar` injector, every spawn and every
//! pop of every worker serialized on one cache line.

use std::collections::VecDeque;
use std::sync::Mutex;

use super::Job;

/// A single worker's job deque. Owner end = back, thief end = front.
pub struct WorkerDeque {
    jobs: Mutex<VecDeque<Job>>,
}

impl WorkerDeque {
    pub fn new() -> Self {
        WorkerDeque { jobs: Mutex::new(VecDeque::new()) }
    }

    /// Owner push (back). Only the owning worker calls this.
    pub fn push(&self, job: Job) {
        self.jobs.lock().unwrap().push_back(job);
    }

    /// Owner pop (back, LIFO).
    pub fn pop(&self) -> Option<Job> {
        self.jobs.lock().unwrap().pop_back()
    }

    /// Thief pop (front, FIFO).
    pub fn steal(&self) -> Option<Job> {
        self.jobs.lock().unwrap().pop_front()
    }

    /// Queued jobs (instantaneous; for stats and idle checks).
    pub fn len(&self) -> usize {
        self.jobs.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Take everything (worker exit path).
    pub fn drain(&self) -> Vec<Job> {
        self.jobs.lock().unwrap().drain(..).collect()
    }
}

impl Default for WorkerDeque {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn job(order: &Arc<Mutex<Vec<u32>>>, tag: u32) -> Job {
        let order = Arc::clone(order);
        Box::new(move || order.lock().unwrap().push(tag))
    }

    #[test]
    fn owner_is_lifo_thief_is_fifo() {
        let d = WorkerDeque::new();
        let order = Arc::new(Mutex::new(Vec::new()));
        for tag in 0..4 {
            d.push(job(&order, tag));
        }
        // Thief sees the oldest job…
        d.steal().unwrap()();
        // …the owner the newest.
        d.pop().unwrap()();
        assert_eq!(*order.lock().unwrap(), vec![0, 3]);
        assert_eq!(d.len(), 2);
    }

    #[test]
    fn drain_empties() {
        let d = WorkerDeque::new();
        let n = Arc::new(AtomicUsize::new(0));
        for _ in 0..5 {
            let n = n.clone();
            d.push(Box::new(move || {
                n.fetch_add(1, Ordering::SeqCst);
            }));
        }
        let jobs = d.drain();
        assert_eq!(jobs.len(), 5);
        assert!(d.is_empty());
        for j in jobs {
            j();
        }
        assert_eq!(n.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn concurrent_owner_and_thieves_lose_nothing() {
        let d = Arc::new(WorkerDeque::new());
        let done = Arc::new(AtomicUsize::new(0));
        const N: usize = 10_000;
        std::thread::scope(|s| {
            // Owner: push everything, popping occasionally.
            {
                let d = d.clone();
                let done = done.clone();
                s.spawn(move || {
                    for i in 0..N {
                        let done = done.clone();
                        d.push(Box::new(move || {
                            done.fetch_add(1, Ordering::SeqCst);
                        }));
                        if i % 3 == 0 {
                            if let Some(j) = d.pop() {
                                j();
                            }
                        }
                    }
                });
            }
            // Two thieves.
            for _ in 0..2 {
                let d = d.clone();
                let done = done.clone();
                s.spawn(move || {
                    while done.load(Ordering::SeqCst) < N {
                        match d.steal() {
                            Some(j) => j(),
                            None => std::thread::yield_now(),
                        }
                    }
                });
            }
        });
        assert_eq!(done.load(Ordering::SeqCst), N);
    }
}

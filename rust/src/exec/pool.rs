//! Worker pool with work-stealing scheduling and managed blocking (a
//! miniature ForkJoinPool).
//!
//! Scheduling layout under [`Scheduler::WorkStealing`] (the default):
//!
//! * Every worker owns a [`WorkerDeque`] (a lock-free Chase–Lev ring by
//!   default, or the locked baseline — [`ExecutorConfig::deque`]):
//!   local spawns push LIFO onto it (cache-warm continuation runs
//!   next), thieves steal FIFO from the far end (oldest = biggest
//!   remaining subtree).
//! * External submissions (driver threads) land in the global injector.
//! * A worker looks for work in order: own deque → injector → steal from
//!   a rotating start index across the other deques. A steal is a
//!   **batch acquisition**: the thief takes up to half the victim's run
//!   in one visit (`steal_batch_and_pop`), runs the oldest job
//!   immediately, and lands the rest in its own deque — where they are
//!   locally poppable and stealable by third parties. A thief that
//!   lands a batch also wakes one parked peer, so a deep backlog fans
//!   out across the pool instead of draining through one worker.
//! * Finding nothing, it parks on a pool-wide condvar. Producers only
//!   touch that condvar when `idle_workers > 0`, so the saturated hot
//!   path (everyone busy) does no notify work at all.
//!
//! [`Scheduler::GlobalQueue`] keeps every spawn/pop on the single
//! injector: the pre-work-stealing design, preserved as the measured
//! baseline (`BENCH_executor.json` compares the two on the same
//! machine).
//!
//! Idle protocol (lost-wakeup-free): a parking worker *first* increments
//! `idle_workers` (SeqCst), then re-checks for work while holding
//! `park_lock`, and only then waits. A producer pushes its job first and
//! *then* reads `idle_workers`; if it reads 0, the parking worker's
//! increment — and therefore its subsequent work re-check — is ordered
//! after the push, so the worker sees the job instead of sleeping. If it
//! reads > 0 it notifies under `park_lock`, which a mid-transition
//! parker cannot miss.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Duration;

use super::deque::{DequeKind, WorkerDeque};
use super::queue::JobQueue;
use super::{current_worker, set_current_worker, with_current_worker, Job, WorkerCtx};

/// Which scheduling core an [`Executor`] runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheduler {
    /// One shared `Mutex<VecDeque>` for everything — the baseline the
    /// paper-reproduction started from, kept for overhead ablations.
    GlobalQueue,
    /// Per-worker stealable deques + injector + park/unpark (default).
    WorkStealing,
}

/// Tuning knobs for an [`Executor`].
#[derive(Debug, Clone)]
pub struct ExecutorConfig {
    /// Target number of concurrently *running* (non-blocked) workers.
    /// This is the paper's par(n) variable.
    pub parallelism: usize,
    /// Stack size per worker. Recursive stream forcing (the sieve builds a
    /// filter chain thousands of stages deep) needs generous stacks.
    pub stack_size: usize,
    /// How long a compensation (transient) worker lingers idle before
    /// retiring.
    pub keepalive: Duration,
    /// Hard cap on live threads (deadlock insurance must not become a
    /// fork bomb).
    pub max_threads: usize,
    /// Thread-name prefix, for debuggability.
    pub name: String,
    /// Scheduling core. [`Scheduler::WorkStealing`] unless you are
    /// benchmarking against the baseline.
    pub scheduler: Scheduler,
    /// Per-worker deque implementation (work-stealing mode only):
    /// lock-free Chase–Lev ring by default, or the locked baseline for
    /// A/B runs. Defaults to [`DequeKind::default_kind`] (`SFUT_DEQUE`
    /// aware).
    pub deque: DequeKind,
}

impl ExecutorConfig {
    pub fn with_parallelism(parallelism: usize) -> Self {
        ExecutorConfig {
            parallelism: parallelism.max(1),
            stack_size: 64 << 20,
            keepalive: Duration::from_millis(200),
            max_threads: 512,
            name: "sfut-worker".to_string(),
            scheduler: Scheduler::WorkStealing,
            deque: DequeKind::default_kind(),
        }
    }
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        Self::with_parallelism(n)
    }
}

/// Counters exposed by [`Executor::stats`]. All monotonically increasing
/// except `queue_depth`/`live_threads` which are instantaneous.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ExecutorStats {
    pub tasks_spawned: u64,
    pub tasks_executed: u64,
    pub tasks_panicked: u64,
    /// Jobs taken FIFO out of another worker's deque (batch-stolen jobs
    /// included). Zero under [`Scheduler::GlobalQueue`]; nonzero
    /// whenever work-stealing actually balanced load.
    pub tasks_stolen: u64,
    /// Steal operations that moved more than one job (steal-half
    /// batching actually batched).
    pub steals_batched: u64,
    /// Extra jobs landed in thieves' deques by batch steals (excludes
    /// the immediately-run first job of each batch).
    pub jobs_migrated: u64,
    pub compensation_threads: u64,
    pub blocking_sections: u64,
    /// Injector depth plus the sum of all worker-deque depths.
    pub queue_depth: usize,
    pub live_threads: usize,
}

impl ExecutorStats {
    /// Mean batch size of batched steals (extra jobs landed per batch
    /// operation); 0 when nothing batched yet. Published as the
    /// `jobs_migrated_per_steal` gauge (rounded).
    pub fn jobs_migrated_per_steal(&self) -> f64 {
        if self.steals_batched == 0 {
            0.0
        } else {
            self.jobs_migrated as f64 / self.steals_batched as f64
        }
    }
}

pub(crate) struct Inner {
    /// Global injector: external submissions, and everything under
    /// [`Scheduler::GlobalQueue`].
    injector: JobQueue,
    /// Registered worker deques (work-stealing mode only). Read-locked
    /// by steal scans, write-locked on worker birth/retirement.
    deques: RwLock<Vec<Arc<WorkerDeque>>>,
    cfg: ExecutorConfig,
    sync: Mutex<PoolState>,
    idle: Condvar,
    /// Parking for idle workers. Producers take this lock only when
    /// `idle_workers > 0`.
    park_lock: Mutex<()>,
    park_cond: Condvar,
    /// Workers currently inside [`Inner::park`] (SeqCst; see the idle
    /// protocol in the module docs).
    idle_workers: AtomicUsize,
    /// Jobs spawned and not yet finished (queued or running).
    /// Atomic so the per-task hot path never takes `sync` (§Perf opt-2);
    /// `sync` + `idle` are only touched on the 0-transition.
    pending: AtomicUsize,
    // Monotonic counters (lock-free; read by stats()).
    tasks_spawned: AtomicU64,
    tasks_executed: AtomicU64,
    tasks_panicked: AtomicU64,
    tasks_stolen: AtomicU64,
    steals_batched: AtomicU64,
    jobs_migrated: AtomicU64,
    compensation_threads: AtomicU64,
    blocking_sections: AtomicU64,
    /// Rotates the steal scan's start index so thieves spread out.
    steal_seed: AtomicUsize,
    next_worker_id: AtomicUsize,
}

#[derive(Default)]
struct PoolState {
    /// Live worker threads.
    live: usize,
    /// Workers currently inside a managed-blocking section.
    blocked: usize,
}

enum ParkOutcome {
    /// Woken (or found work while double-checking): go look again.
    Notified,
    /// Pool shut down and drained: exit.
    Shutdown,
    /// Transient worker idled past its keepalive: exit.
    Retire,
}

/// Handle to a worker pool. Cloning is cheap; the pool shuts down (after
/// draining queued jobs) when the last external handle is dropped, or
/// eagerly on [`Executor::shutdown`].
#[derive(Clone)]
pub struct Executor {
    handle: Arc<Handle>,
}

struct Handle {
    inner: Arc<Inner>,
}

impl Drop for Handle {
    fn drop(&mut self) {
        self.inner.shutdown();
    }
}

impl Executor {
    /// Pool with `parallelism` workers and default tuning.
    pub fn new(parallelism: usize) -> Self {
        Self::with_config(ExecutorConfig::with_parallelism(parallelism))
    }

    /// Pool sized to the machine.
    pub fn machine_sized() -> Self {
        Self::with_config(ExecutorConfig::default())
    }

    pub fn with_config(cfg: ExecutorConfig) -> Self {
        let inner = Arc::new(Inner {
            injector: JobQueue::new(),
            deques: RwLock::new(Vec::new()),
            cfg,
            sync: Mutex::new(PoolState::default()),
            idle: Condvar::new(),
            park_lock: Mutex::new(()),
            park_cond: Condvar::new(),
            idle_workers: AtomicUsize::new(0),
            pending: AtomicUsize::new(0),
            tasks_spawned: AtomicU64::new(0),
            tasks_executed: AtomicU64::new(0),
            tasks_panicked: AtomicU64::new(0),
            tasks_stolen: AtomicU64::new(0),
            steals_batched: AtomicU64::new(0),
            jobs_migrated: AtomicU64::new(0),
            compensation_threads: AtomicU64::new(0),
            blocking_sections: AtomicU64::new(0),
            steal_seed: AtomicUsize::new(0),
            next_worker_id: AtomicUsize::new(0),
        });
        for _ in 0..inner.cfg.parallelism {
            Inner::spawn_worker(&inner, false);
        }
        Executor { handle: Arc::new(Handle { inner }) }
    }

    /// Configured parallelism (the paper's par(n)).
    pub fn parallelism(&self) -> usize {
        self.handle.inner.cfg.parallelism
    }

    /// The scheduling core this pool runs.
    pub fn scheduler(&self) -> Scheduler {
        self.handle.inner.cfg.scheduler
    }

    /// Submit a job. Jobs submitted after shutdown are silently dropped.
    /// When the caller is a worker of this pool (and the scheduler is
    /// work-stealing), the job goes LIFO onto the worker's own deque;
    /// otherwise it lands in the global injector.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.handle.inner.spawn_job(Box::new(f));
    }

    /// Run `f`, which may block, from inside a worker without starving the
    /// pool: the calling worker is marked blocked and a compensation
    /// worker is started so the configured parallelism is preserved.
    /// Safe (and a no-op wrapper) on non-worker threads.
    ///
    /// This is the moral equivalent of Scala's
    /// `scala.concurrent.blocking { ... }` that backs `Await.result`.
    pub fn blocking<R>(f: impl FnOnce() -> R) -> R {
        match current_worker() {
            Some(ctx) => ctx.inner.managed_blocking(f),
            None => f(),
        }
    }

    /// Block until no job is pending (queued or running). Jobs spawned by
    /// running jobs are awaited too.
    pub fn wait_idle(&self) {
        let inner = &self.handle.inner;
        let mut st = inner.sync.lock().unwrap();
        while inner.pending.load(Ordering::Acquire) > 0 {
            st = inner.idle.wait(st).unwrap();
        }
        drop(st);
    }

    /// Eagerly shut down; queued jobs drain, workers then exit.
    pub fn shutdown(&self) {
        self.handle.inner.shutdown();
    }

    pub fn stats(&self) -> ExecutorStats {
        let inner = &self.handle.inner;
        let st = inner.sync.lock().unwrap();
        let deque_depth: usize =
            inner.deques.read().unwrap().iter().map(|d| d.len()).sum();
        ExecutorStats {
            tasks_spawned: inner.tasks_spawned.load(Ordering::Relaxed),
            tasks_executed: inner.tasks_executed.load(Ordering::Relaxed),
            tasks_panicked: inner.tasks_panicked.load(Ordering::Relaxed),
            tasks_stolen: inner.tasks_stolen.load(Ordering::Relaxed),
            steals_batched: inner.steals_batched.load(Ordering::Relaxed),
            jobs_migrated: inner.jobs_migrated.load(Ordering::Relaxed),
            compensation_threads: inner.compensation_threads.load(Ordering::Relaxed),
            blocking_sections: inner.blocking_sections.load(Ordering::Relaxed),
            queue_depth: inner.injector.len() + deque_depth,
            live_threads: st.live,
        }
    }
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor")
            .field("parallelism", &self.handle.inner.cfg.parallelism)
            .field("scheduler", &self.handle.inner.cfg.scheduler)
            .finish()
    }
}

impl Inner {
    fn spawn_job(self: &Arc<Self>, job: Job) {
        self.tasks_spawned.fetch_add(1, Ordering::Relaxed);
        self.pending.fetch_add(1, Ordering::AcqRel);
        if self.injector.is_shutdown() {
            // Shut down: account the drop so wait_idle terminates.
            self.finish_job_accounting();
            return;
        }
        // Local fast path: a worker of THIS pool pushes LIFO onto its own
        // deque — uncontended in the common case, and no global lock.
        enum LocalPush {
            Pushed,
            /// Shutdown raced the push; the job was retracted and dropped.
            Dropped,
            NotLocal,
        }
        let mut job = Some(job);
        let pushed_local = with_current_worker(|ctx| match ctx {
            Some(ctx) if Arc::ptr_eq(&ctx.inner, self) => match &ctx.deque {
                Some(d) => {
                    // SAFETY: `ctx.deque` is the calling worker's own
                    // deque (thread-local context) — this thread is its
                    // sole owner.
                    unsafe { d.push(job.take().expect("job not yet consumed")) };
                    // Close the spawn/shutdown race (the old global queue
                    // checked the flag under its lock): if shutdown landed
                    // between the check above and the push, retract the
                    // job — it is the newest entry at the back of our own
                    // deque, so `pop` returns exactly it unless a thief
                    // already claimed it (in which case it is in flight,
                    // same as a pre-shutdown submission).
                    // SAFETY: same owner-only argument as the push above.
                    if self.injector.is_shutdown() && unsafe { d.pop() }.is_some() {
                        LocalPush::Dropped
                    } else {
                        LocalPush::Pushed
                    }
                }
                None => LocalPush::NotLocal,
            },
            _ => LocalPush::NotLocal,
        });
        match pushed_local {
            LocalPush::Pushed => {
                self.notify_parked();
                return;
            }
            LocalPush::Dropped => {
                self.finish_job_accounting();
                return;
            }
            LocalPush::NotLocal => {}
        }
        let job = job.take().expect("job not yet consumed");
        if !self.injector.push(job) {
            // Shut down between the check and the push.
            self.finish_job_accounting();
            return;
        }
        self.notify_parked();
    }

    /// Wake one parked worker if any exist. Producers read `idle_workers`
    /// first so the saturated fast path never touches `park_lock`.
    fn notify_parked(&self) {
        if self.idle_workers.load(Ordering::SeqCst) > 0 {
            let _guard = self.park_lock.lock().unwrap();
            self.park_cond.notify_one();
        }
    }

    fn shutdown(&self) {
        self.injector.shutdown();
        let _guard = self.park_lock.lock().unwrap();
        self.park_cond.notify_all();
    }

    /// Decrement `pending`; on the 0-transition, wake idle waiters. The
    /// brief `sync` lock pairs with `wait_idle`'s check-under-lock so a
    /// waiter cannot sleep between its check and our notify.
    fn finish_job_accounting(&self) {
        if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _guard = self.sync.lock().unwrap();
            self.idle.notify_all();
        }
    }

    fn spawn_worker(self: &Arc<Self>, transient: bool) {
        let mut st = self.sync.lock().unwrap();
        if st.live >= self.cfg.max_threads {
            return; // cap reached; queued work will be picked up eventually
        }
        st.live += 1;
        drop(st);
        if transient {
            self.compensation_threads.fetch_add(1, Ordering::Relaxed);
        }
        let id = self.next_worker_id.fetch_add(1, Ordering::Relaxed);
        let me = Arc::clone(self);
        let name = format!("{}-{}{}", self.cfg.name, if transient { "c" } else { "" }, id);
        let spawned = std::thread::Builder::new()
            .name(name)
            .stack_size(self.cfg.stack_size)
            .spawn(move || me.worker_loop(transient));
        if spawned.is_err() {
            // Could not start a thread: undo the liveness accounting.
            let mut st = self.sync.lock().unwrap();
            st.live -= 1;
        }
    }

    fn worker_loop(self: Arc<Self>, transient: bool) {
        let deque = match self.cfg.scheduler {
            Scheduler::WorkStealing => Some(Arc::new(WorkerDeque::with_kind(self.cfg.deque))),
            Scheduler::GlobalQueue => None,
        };
        if let Some(d) = &deque {
            self.deques.write().unwrap().push(Arc::clone(d));
        }
        set_current_worker(Some(WorkerCtx {
            inner: Arc::clone(&self),
            deque: deque.clone(),
        }));
        let keepalive = if transient { Some(self.cfg.keepalive) } else { None };
        loop {
            if let Some(job) = self.find_job(deque.as_deref()) {
                self.run_job(job);
                continue;
            }
            match self.park(keepalive) {
                ParkOutcome::Notified => continue,
                ParkOutcome::Shutdown | ParkOutcome::Retire => {
                    // Commit the exit under `sync`, with a final work
                    // re-check. managed_blocking reads `live` under the
                    // same lock to size compensation, so without this a
                    // job pushed + blocked-on in the window between our
                    // park timeout and the decrement would see a worker
                    // that is about to vanish, skip compensation, and
                    // deadlock par(1). Ordering both ways is now safe:
                    // either the blocker sees the reduced count and
                    // compensates, or we see its job here and un-retire.
                    let mut st = self.sync.lock().unwrap();
                    if self.has_work() {
                        drop(st);
                        continue;
                    }
                    st.live -= 1;
                    break;
                }
            }
        }
        set_current_worker(None);
        if let Some(d) = &deque {
            self.deques.write().unwrap().retain(|q| !Arc::ptr_eq(q, d));
            // Exit paths imply the deque is empty; if a job is ever left
            // behind, hand it back and wake a worker for it rather than
            // stranding it (and a wait_idle caller) until the next spawn.
            // SAFETY: this worker thread created the deque and is its
            // sole owner; the write-locked `retain` above means no thief
            // can reach it anymore either.
            for job in unsafe { d.drain() } {
                if self.injector.push(job) {
                    self.notify_parked();
                } else {
                    self.finish_job_accounting();
                }
            }
        }
    }

    /// Work-discovery order: own deque (LIFO) → injector → steal (FIFO).
    fn find_job(&self, own: Option<&WorkerDeque>) -> Option<Job> {
        if let Some(d) = own {
            // SAFETY: `own` is the calling worker's deque — this thread
            // is its sole owner.
            if let Some(job) = unsafe { d.pop() } {
                return Some(job);
            }
        }
        if let Some(job) = self.injector.try_pop() {
            return Some(job);
        }
        self.try_steal(own)
    }

    fn try_steal(&self, own: Option<&WorkerDeque>) -> Option<Job> {
        // Whether a landed batch should wake a parked peer. The notify
        // happens *after* the deques read guard drops: notify_parked
        // takes park_lock, and a parker holds park_lock while its
        // has_work re-check takes the deques read lock — notifying
        // under the guard could deadlock through a queued writer.
        let mut landed_batch = false;
        let mut found = None;
        {
            let deques = self.deques.read().unwrap();
            let n = deques.len();
            if n == 0 {
                return None;
            }
            let start = self.steal_seed.fetch_add(1, Ordering::Relaxed) % n;
            for k in 0..n {
                let q = &deques[(start + k) % n];
                match own {
                    Some(own) => {
                        if std::ptr::eq(Arc::as_ptr(q), own) {
                            continue;
                        }
                        // Batch acquisition: land up to half the
                        // victim's run in our own deque, run the oldest
                        // job now.
                        // SAFETY: `own` is the calling worker's deque —
                        // this thread owns the destination end.
                        if let Some((job, moved)) = unsafe { q.steal_batch_and_pop(own) } {
                            self.tasks_stolen.fetch_add(1 + moved as u64, Ordering::Relaxed);
                            if moved > 0 {
                                self.steals_batched.fetch_add(1, Ordering::Relaxed);
                                self.jobs_migrated.fetch_add(moved as u64, Ordering::Relaxed);
                                landed_batch = true;
                            }
                            found = Some(job);
                            break;
                        }
                    }
                    None => {
                        // No home deque to land a batch in (e.g. a
                        // worker of a GlobalQueue pool would not get
                        // here at all): plain single steal.
                        if let Some(job) = q.steal() {
                            self.tasks_stolen.fetch_add(1, Ordering::Relaxed);
                            found = Some(job);
                            break;
                        }
                    }
                }
            }
        }
        if landed_batch {
            // The migrated jobs are poppable by us and stealable from
            // our deque; wake one parked peer to help drain the backlog
            // (parking re-checks has_work, so this is purely a latency
            // hint, never a correctness need).
            self.notify_parked();
        }
        found
    }

    /// True when any queue in the pool holds a job.
    fn has_work(&self) -> bool {
        if !self.injector.is_empty() {
            return true;
        }
        self.deques.read().unwrap().iter().any(|d| !d.is_empty())
    }

    /// Park until notified, shutdown, or (transient workers) keepalive
    /// expiry. See the module docs for why the idle-registration order
    /// makes this lost-wakeup-free.
    fn park(&self, keepalive: Option<Duration>) -> ParkOutcome {
        self.idle_workers.fetch_add(1, Ordering::SeqCst);
        let mut guard = self.park_lock.lock().unwrap();
        let outcome = loop {
            if self.has_work() {
                break ParkOutcome::Notified;
            }
            if self.injector.is_shutdown() {
                break ParkOutcome::Shutdown;
            }
            match keepalive {
                Some(t) => {
                    let (g, res) = self.park_cond.wait_timeout(guard, t).unwrap();
                    guard = g;
                    if res.timed_out() {
                        break if self.has_work() {
                            ParkOutcome::Notified
                        } else {
                            ParkOutcome::Retire
                        };
                    }
                }
                None => {
                    guard = self.park_cond.wait(guard).unwrap();
                }
            }
        };
        drop(guard);
        self.idle_workers.fetch_sub(1, Ordering::SeqCst);
        outcome
    }

    fn run_job(self: &Arc<Self>, job: Job) {
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        self.tasks_executed.fetch_add(1, Ordering::Relaxed);
        if res.is_err() {
            // The panic belongs to the task, not the worker. Futures built
            // on this pool catch their own panics before this point; a bare
            // spawn that panics is counted and swallowed.
            self.tasks_panicked.fetch_add(1, Ordering::Relaxed);
        }
        self.finish_job_accounting();
    }

    fn managed_blocking<R>(self: Arc<Self>, f: impl FnOnce() -> R) -> R {
        self.blocking_sections.fetch_add(1, Ordering::Relaxed);
        {
            let mut st = self.sync.lock().unwrap();
            st.blocked += 1;
            let running = st.live - st.blocked;
            let need_compensation = running < self.cfg.parallelism;
            drop(st);
            if need_compensation {
                self.spawn_worker(true);
            }
        }
        // The closure may itself re-enter the executor; keep the worker
        // marker in place so nested blocking also compensates.
        let out = f();
        let mut st = self.sync.lock().unwrap();
        st.blocked -= 1;
        out
    }
}

//! Worker pool with managed blocking (a miniature ForkJoinPool).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::queue::{JobQueue, Popped};
use super::{current_worker, set_current_worker, Job};

/// Tuning knobs for an [`Executor`].
#[derive(Debug, Clone)]
pub struct ExecutorConfig {
    /// Target number of concurrently *running* (non-blocked) workers.
    /// This is the paper's par(n) variable.
    pub parallelism: usize,
    /// Stack size per worker. Recursive stream forcing (the sieve builds a
    /// filter chain thousands of stages deep) needs generous stacks.
    pub stack_size: usize,
    /// How long a compensation (transient) worker lingers idle before
    /// retiring.
    pub keepalive: Duration,
    /// Hard cap on live threads (deadlock insurance must not become a
    /// fork bomb).
    pub max_threads: usize,
    /// Thread-name prefix, for debuggability.
    pub name: String,
}

impl ExecutorConfig {
    pub fn with_parallelism(parallelism: usize) -> Self {
        ExecutorConfig {
            parallelism: parallelism.max(1),
            stack_size: 64 << 20,
            keepalive: Duration::from_millis(200),
            max_threads: 512,
            name: "sfut-worker".to_string(),
        }
    }
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        Self::with_parallelism(n)
    }
}

/// Counters exposed by [`Executor::stats`]. All monotonically increasing
/// except `queue_depth`/`live_threads` which are instantaneous.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ExecutorStats {
    pub tasks_spawned: u64,
    pub tasks_executed: u64,
    pub tasks_panicked: u64,
    pub compensation_threads: u64,
    pub blocking_sections: u64,
    pub queue_depth: usize,
    pub live_threads: usize,
}

pub(crate) struct Inner {
    pub(crate) queue: JobQueue,
    cfg: ExecutorConfig,
    sync: Mutex<PoolState>,
    idle: Condvar,
    /// Jobs spawned and not yet finished (queued or running).
    /// Atomic so the per-task hot path never takes `sync` (§Perf opt-2);
    /// `sync` + `idle` are only touched on the 0-transition.
    pending: AtomicUsize,
    // Monotonic counters (lock-free; read by stats()).
    tasks_spawned: AtomicU64,
    tasks_executed: AtomicU64,
    tasks_panicked: AtomicU64,
    compensation_threads: AtomicU64,
    blocking_sections: AtomicU64,
    next_worker_id: AtomicUsize,
}

#[derive(Default)]
struct PoolState {
    /// Live worker threads.
    live: usize,
    /// Workers currently inside a managed-blocking section.
    blocked: usize,
}

/// Handle to a worker pool. Cloning is cheap; the pool shuts down (after
/// draining queued jobs) when the last external handle is dropped, or
/// eagerly on [`Executor::shutdown`].
#[derive(Clone)]
pub struct Executor {
    handle: Arc<Handle>,
}

struct Handle {
    inner: Arc<Inner>,
}

impl Drop for Handle {
    fn drop(&mut self) {
        self.inner.queue.shutdown();
    }
}

impl Executor {
    /// Pool with `parallelism` workers and default tuning.
    pub fn new(parallelism: usize) -> Self {
        Self::with_config(ExecutorConfig::with_parallelism(parallelism))
    }

    /// Pool sized to the machine.
    pub fn machine_sized() -> Self {
        Self::with_config(ExecutorConfig::default())
    }

    pub fn with_config(cfg: ExecutorConfig) -> Self {
        let inner = Arc::new(Inner {
            queue: JobQueue::new(),
            cfg,
            sync: Mutex::new(PoolState::default()),
            idle: Condvar::new(),
            pending: AtomicUsize::new(0),
            tasks_spawned: AtomicU64::new(0),
            tasks_executed: AtomicU64::new(0),
            tasks_panicked: AtomicU64::new(0),
            compensation_threads: AtomicU64::new(0),
            blocking_sections: AtomicU64::new(0),
            next_worker_id: AtomicUsize::new(0),
        });
        for _ in 0..inner.cfg.parallelism {
            Inner::spawn_worker(&inner, false);
        }
        Executor { handle: Arc::new(Handle { inner }) }
    }

    /// Configured parallelism (the paper's par(n)).
    pub fn parallelism(&self) -> usize {
        self.handle.inner.cfg.parallelism
    }

    /// Submit a job. Jobs submitted after shutdown are silently dropped.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.handle.inner.spawn_job(Box::new(f));
    }

    /// Run `f`, which may block, from inside a worker without starving the
    /// pool: the calling worker is marked blocked and a compensation
    /// worker is started so the configured parallelism is preserved.
    /// Safe (and a no-op wrapper) on non-worker threads.
    ///
    /// This is the moral equivalent of Scala's
    /// `scala.concurrent.blocking { ... }` that backs `Await.result`.
    pub fn blocking<R>(f: impl FnOnce() -> R) -> R {
        match current_worker() {
            Some(inner) => inner.managed_blocking(f),
            None => f(),
        }
    }

    /// Block until no job is pending (queued or running). Jobs spawned by
    /// running jobs are awaited too.
    pub fn wait_idle(&self) {
        let inner = &self.handle.inner;
        let mut st = inner.sync.lock().unwrap();
        while inner.pending.load(Ordering::Acquire) > 0 {
            st = inner.idle.wait(st).unwrap();
        }
        drop(st);
    }

    /// Eagerly shut down; queued jobs drain, workers then exit.
    pub fn shutdown(&self) {
        self.handle.inner.queue.shutdown();
    }

    pub fn stats(&self) -> ExecutorStats {
        let inner = &self.handle.inner;
        let st = inner.sync.lock().unwrap();
        ExecutorStats {
            tasks_spawned: inner.tasks_spawned.load(Ordering::Relaxed),
            tasks_executed: inner.tasks_executed.load(Ordering::Relaxed),
            tasks_panicked: inner.tasks_panicked.load(Ordering::Relaxed),
            compensation_threads: inner.compensation_threads.load(Ordering::Relaxed),
            blocking_sections: inner.blocking_sections.load(Ordering::Relaxed),
            queue_depth: inner.queue.len(),
            live_threads: st.live,
        }
    }
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor")
            .field("parallelism", &self.handle.inner.cfg.parallelism)
            .finish()
    }
}

impl Inner {
    fn spawn_job(self: &Arc<Self>, job: Job) {
        self.tasks_spawned.fetch_add(1, Ordering::Relaxed);
        self.pending.fetch_add(1, Ordering::AcqRel);
        if !self.queue.push(job) {
            // Shut down: account the drop so wait_idle terminates.
            self.finish_job_accounting();
        }
    }

    /// Decrement `pending`; on the 0-transition, wake idle waiters. The
    /// brief `sync` lock pairs with `wait_idle`'s check-under-lock so a
    /// waiter cannot sleep between its check and our notify.
    fn finish_job_accounting(&self) {
        if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _guard = self.sync.lock().unwrap();
            self.idle.notify_all();
        }
    }

    fn spawn_worker(self: &Arc<Self>, transient: bool) {
        let mut st = self.sync.lock().unwrap();
        if st.live >= self.cfg.max_threads {
            return; // cap reached; queued work will be picked up eventually
        }
        st.live += 1;
        drop(st);
        if transient {
            self.compensation_threads.fetch_add(1, Ordering::Relaxed);
        }
        let id = self.next_worker_id.fetch_add(1, Ordering::Relaxed);
        let me = Arc::clone(self);
        let name = format!("{}-{}{}", self.cfg.name, if transient { "c" } else { "" }, id);
        let spawned = std::thread::Builder::new()
            .name(name)
            .stack_size(self.cfg.stack_size)
            .spawn(move || me.worker_loop(transient));
        if spawned.is_err() {
            // Could not start a thread: undo the liveness accounting.
            let mut st = self.sync.lock().unwrap();
            st.live -= 1;
        }
    }

    fn worker_loop(self: Arc<Self>, transient: bool) {
        set_current_worker(Some(Arc::clone(&self)));
        let timeout = if transient { Some(self.cfg.keepalive) } else { None };
        loop {
            match self.queue.pop(timeout) {
                Popped::Job(job) => self.run_job(job),
                Popped::Shutdown => break,
                Popped::TimedOut => break, // transient worker retires
            }
        }
        set_current_worker(None);
        let mut st = self.sync.lock().unwrap();
        st.live -= 1;
    }

    fn run_job(self: &Arc<Self>, job: Job) {
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        self.tasks_executed.fetch_add(1, Ordering::Relaxed);
        if res.is_err() {
            // The panic belongs to the task, not the worker. Futures built
            // on this pool catch their own panics before this point; a bare
            // spawn that panics is counted and swallowed.
            self.tasks_panicked.fetch_add(1, Ordering::Relaxed);
        }
        self.finish_job_accounting();
    }

    fn managed_blocking<R>(self: Arc<Self>, f: impl FnOnce() -> R) -> R {
        self.blocking_sections.fetch_add(1, Ordering::Relaxed);
        {
            let mut st = self.sync.lock().unwrap();
            st.blocked += 1;
            let running = st.live - st.blocked;
            let need_compensation = running < self.cfg.parallelism;
            drop(st);
            if need_compensation {
                self.spawn_worker(true);
            }
        }
        // The closure may itself re-enter the executor; keep the worker
        // marker in place so nested blocking also compensates.
        let out = f();
        let mut st = self.sync.lock().unwrap();
        st.blocked -= 1;
        out
    }
}

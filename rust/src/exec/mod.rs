//! Task executor substrate.
//!
//! The paper relies on Scala's `scala.concurrent` machinery: a thread pool
//! with *managed blocking* (the JVM `ForkJoinPool` grows compensation
//! threads when a worker blocks in `Await.result`). Nothing equivalent is
//! available offline, and the Future machinery is the paper's subject, so
//! this module builds it from scratch:
//!
//! * [`Executor`] — a fixed-parallelism worker pool. Scheduling is
//!   **work-stealing** by default ([`Scheduler::WorkStealing`]): each
//!   worker owns a [`WorkerDeque`] with LIFO local push/pop and FIFO
//!   stealing — a lock-free Chase–Lev ring deque ([`ChaseLevDeque`],
//!   the default) or the minimally-locked baseline ([`LockedDeque`]),
//!   selected at runtime via [`DequeKind`] (`Config::deque`,
//!   `SFUT_DEQUE`). Thieves use **steal-half batching**: one victim
//!   visit moves up to half the victim's run into the thief's own deque
//!   (`ExecutorStats::{steals_batched, jobs_migrated}` count it).
//!   External submissions land in a global injector ([`JobQueue`]), and
//!   idle workers park on a pool-wide condvar until a producer unparks
//!   them. The old single-lock injector survives as
//!   [`Scheduler::GlobalQueue`], kept as the measured baseline for
//!   `benches/ablation_overhead.rs` / `BENCH_executor.json`, which now
//!   records `deque=chase_lev` vs `deque=locked` A/B datapoints from
//!   the same harness run.
//! * Managed blocking ([`Executor::blocking`]) — when a worker is about to
//!   block (the paper's `Await.result` inside `plus`), a compensation
//!   worker is spun up so the configured parallelism is preserved and
//!   `par(1)` cannot deadlock on a dependency chain. Compensation workers
//!   register their own deques and steal like any other worker.
//! * Panic propagation — a panicking task poisons its future; the panic
//!   payload resurfaces at the `force` site, not in a dead worker log.
//!   This holds for stolen tasks too (the catch sits in the job body, so
//!   it travels with the job wherever it runs).
//!
//! The pool size is the experimental variable of the paper's evaluation:
//! `par(1)` and `par(2)` in Table 1 are literally `Executor::new(1)` and
//! `Executor::new(2)`.

mod deque;
mod pool;
mod queue;

pub use deque::{ChaseLevDeque, DequeKind, LockedDeque, WorkerDeque, MAX_STEAL_BATCH};
pub use pool::{Executor, ExecutorConfig, ExecutorStats, Scheduler};
pub use queue::JobQueue;

use std::sync::Arc;

/// A unit of work submitted to the executor.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

/// What a worker thread knows about itself: its pool, and (under the
/// work-stealing scheduler) its own deque for LIFO local pushes.
#[derive(Clone)]
pub(crate) struct WorkerCtx {
    pub(crate) inner: Arc<pool::Inner>,
    pub(crate) deque: Option<Arc<deque::WorkerDeque>>,
}

thread_local! {
    /// Set while a worker thread is running jobs, so [`current_worker`]
    /// can detect "am I on the pool?" (needed for managed blocking and
    /// the local-spawn fast path).
    static CURRENT: std::cell::RefCell<Option<WorkerCtx>> =
        const { std::cell::RefCell::new(None) };
}

/// Returns the worker context of the current thread, or `None` when
/// called from an external (driver) thread.
pub(crate) fn current_worker() -> Option<WorkerCtx> {
    CURRENT.with(|c| c.borrow().clone())
}

/// Run `f` with a borrow of the current worker context — the
/// allocation-free variant of [`current_worker`] for the spawn hot path
/// (no `Arc` refcount traffic).
pub(crate) fn with_current_worker<R>(f: impl FnOnce(Option<&WorkerCtx>) -> R) -> R {
    CURRENT.with(|c| f(c.borrow().as_ref()))
}

pub(crate) fn set_current_worker(ctx: Option<WorkerCtx>) {
    CURRENT.with(|c| *c.borrow_mut() = ctx);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    use std::time::Duration;

    #[test]
    fn runs_submitted_jobs() {
        let ex = Executor::new(2);
        let n = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let n = n.clone();
            ex.spawn(move || {
                n.fetch_add(1, Ordering::SeqCst);
            });
        }
        ex.wait_idle();
        assert_eq!(n.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallelism_one_still_progresses_with_blocking() {
        // A task that blocks waiting for a later task must not deadlock a
        // 1-worker pool: managed blocking spawns a compensation worker,
        // which steals the producer task out of the blocked worker's
        // deque.
        let ex = Executor::new(1);
        let (tx, rx) = std::sync::mpsc::channel::<u32>();
        let ex2 = ex.clone();
        let (done_tx, done_rx) = std::sync::mpsc::channel::<u32>();
        ex.spawn(move || {
            // Schedule the producer *after* we are already running.
            ex2.spawn(move || {
                tx.send(42).unwrap();
            });
            // Block for its result under managed blocking.
            let v = Executor::blocking(|| rx.recv().unwrap());
            done_tx.send(v).unwrap();
        });
        let got = done_rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(got, 42);
    }

    #[test]
    fn observes_configured_parallelism() {
        // With parallelism=2, at most 2 non-blocked jobs run at once.
        let ex = Executor::new(2);
        let running = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        for _ in 0..32 {
            let running = running.clone();
            let peak = peak.clone();
            ex.spawn(move || {
                let now = running.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(2));
                running.fetch_sub(1, Ordering::SeqCst);
            });
        }
        ex.wait_idle();
        assert!(peak.load(Ordering::SeqCst) <= 2, "peak={}", peak.load(Ordering::SeqCst));
        assert!(peak.load(Ordering::SeqCst) >= 1);
    }

    #[test]
    fn wait_idle_sees_recursive_spawns() {
        let ex = Executor::new(4);
        let hits = Arc::new(AtomicUsize::new(0));
        let ex2 = ex.clone();
        let hits2 = hits.clone();
        ex.spawn(move || {
            hits2.fetch_add(1, Ordering::SeqCst);
            for _ in 0..10 {
                let hits3 = hits2.clone();
                ex2.spawn(move || {
                    hits3.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        ex.wait_idle();
        assert_eq!(hits.load(Ordering::SeqCst), 11);
    }

    #[test]
    fn stats_count_executed_tasks() {
        let ex = Executor::new(2);
        for _ in 0..10 {
            ex.spawn(|| {});
        }
        ex.wait_idle();
        let stats = ex.stats();
        assert_eq!(stats.tasks_executed, 10);
    }

    #[test]
    fn panicked_task_does_not_kill_pool() {
        let ex = Executor::new(1);
        ex.spawn(|| panic!("boom"));
        let ok = Arc::new(AtomicUsize::new(0));
        let ok2 = ok.clone();
        ex.spawn(move || {
            ok2.store(1, Ordering::SeqCst);
        });
        ex.wait_idle();
        assert_eq!(ok.load(Ordering::SeqCst), 1);
        assert_eq!(ex.stats().tasks_panicked, 1);
    }

    #[test]
    fn heavy_contention_completes() {
        let ex = Executor::new(4);
        let total = Arc::new(AtomicUsize::new(0));
        for _ in 0..10_000 {
            let total = total.clone();
            ex.spawn(move || {
                total.fetch_add(1, Ordering::SeqCst);
            });
        }
        ex.wait_idle();
        assert_eq!(total.load(Ordering::SeqCst), 10_000);
    }

    #[test]
    fn results_collected_in_order_via_mutex() {
        let ex = Executor::new(3);
        let out = Arc::new(Mutex::new(Vec::new()));
        for i in 0..50u32 {
            let out = out.clone();
            ex.spawn(move || out.lock().unwrap().push(i));
        }
        ex.wait_idle();
        let mut v = out.lock().unwrap().clone();
        v.sort_unstable();
        assert_eq!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn global_queue_baseline_still_works() {
        // The measured baseline configuration must stay functional: it is
        // the denominator of BENCH_executor.json.
        let mut cfg = ExecutorConfig::with_parallelism(2);
        cfg.scheduler = Scheduler::GlobalQueue;
        let ex = Executor::with_config(cfg);
        let n = Arc::new(AtomicUsize::new(0));
        let ex2 = ex.clone();
        let n2 = n.clone();
        ex.spawn(move || {
            n2.fetch_add(1, Ordering::SeqCst);
            for _ in 0..50 {
                let n3 = n2.clone();
                ex2.spawn(move || {
                    n3.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        ex.wait_idle();
        assert_eq!(n.load(Ordering::SeqCst), 51);
        assert_eq!(ex.stats().tasks_stolen, 0, "no deques to steal from");
    }

    #[test]
    fn both_deque_kinds_drive_the_pool() {
        // The deque implementation is runtime-selectable; the pool must
        // be correct (no lost or duplicated jobs) under either.
        for kind in DequeKind::ALL {
            let mut cfg = ExecutorConfig::with_parallelism(4);
            cfg.deque = kind;
            let ex = Executor::with_config(cfg);
            let n = Arc::new(AtomicUsize::new(0));
            let ex2 = ex.clone();
            let n2 = n.clone();
            ex.spawn(move || {
                for _ in 0..2_000 {
                    let n3 = n2.clone();
                    ex2.spawn(move || {
                        n3.fetch_add(1, Ordering::SeqCst);
                    });
                }
            });
            ex.wait_idle();
            assert_eq!(n.load(Ordering::SeqCst), 2_000, "kind={kind:?}");
            let stats = ex.stats();
            // Batch accounting consistency: a migrated job implies a
            // batched steal, and every migrated job is also a stolen
            // job.
            assert!(stats.jobs_migrated == 0 || stats.steals_batched > 0, "kind={kind:?}");
            assert!(stats.tasks_stolen >= stats.jobs_migrated, "kind={kind:?}");
        }
    }

    #[test]
    fn worker_local_spawns_are_stealable() {
        // One worker floods its own deque then sleeps; the only way the
        // children can run while it sleeps is theft by the other workers.
        let ex = Executor::new(4);
        let n = Arc::new(AtomicUsize::new(0));
        let ex2 = ex.clone();
        let n2 = n.clone();
        ex.spawn(move || {
            for _ in 0..500 {
                let n3 = n2.clone();
                ex2.spawn(move || {
                    n3.fetch_add(1, Ordering::SeqCst);
                });
            }
            std::thread::sleep(Duration::from_millis(50));
        });
        ex.wait_idle();
        assert_eq!(n.load(Ordering::SeqCst), 500);
        assert!(ex.stats().tasks_stolen > 0, "expected nonzero steals");
    }
}

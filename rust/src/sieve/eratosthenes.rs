//! Classical sieve of Eratosthenes — the correctness oracle for every
//! stream-sieve configuration (not part of the paper's evaluation; the
//! paper's baseline for *timings* is the parallel-collections `list`
//! workload, which applies to the polynomial example only).

/// All primes strictly below `n`.
pub fn eratosthenes(n: u32) -> Vec<u32> {
    if n <= 2 {
        return Vec::new();
    }
    let n = n as usize;
    let mut composite = vec![false; n];
    let mut out = Vec::new();
    for p in 2..n {
        if composite[p] {
            continue;
        }
        out.push(p as u32);
        let mut m = p * p;
        while m < n {
            composite[m] = true;
            m += p;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_cases() {
        assert!(eratosthenes(0).is_empty());
        assert!(eratosthenes(2).is_empty());
        assert_eq!(eratosthenes(3), vec![2]);
        assert_eq!(eratosthenes(10), vec![2, 3, 5, 7]);
    }

    #[test]
    fn prime_counting_checkpoints() {
        // π(10^k) reference values.
        assert_eq!(eratosthenes(10).len(), 4);
        assert_eq!(eratosthenes(100).len(), 25);
        assert_eq!(eratosthenes(1_000).len(), 168);
        assert_eq!(eratosthenes(10_000).len(), 1_229);
        assert_eq!(eratosthenes(100_000).len(), 9_592);
    }

    #[test]
    fn paper_workload_sizes() {
        assert_eq!(eratosthenes(20_000).len(), 2_262); // primes
        assert_eq!(eratosthenes(60_000).len(), 6_057); // primes_x3
    }
}

//! Chunked sieve — the §7 improvement applied to the primes workload.
//!
//! The paper's observation 1 blames the sieve's failure to scale on
//! too-fine elementary operations (one task per stream cell). Here the
//! elementary unit is a *block* of candidates:
//!
//! 1. **Seed phase (sequential):** sieve candidates up to `√n` with
//!    per-block trial division, accumulating the seed primes.
//! 2. **Fan-out phase (parallel):** every remaining block only needs the
//!    seed primes to be decided, so blocks become independent tasks in a
//!    future stream — exactly the coarsening §7 asks for.
//!
//! Per-block divisibility testing is a dense `candidates × primes`
//! remainder grid: the [`BlockSiever`] trait lets the runtime swap in the
//! AOT-compiled Pallas kernel (`sieve_mask`) for the inner loop.
//!
//! Note: using the `√n` cutoff is mathematically sound but departs from
//! the paper's deliberately naive sieve (which divides by every smaller
//! prime); the chunked variant is *our* extension of the paper's future
//! work, benchmarked as `A1`/`A2`, never as a reproduction of Table 1's
//! `primes` rows.

use std::sync::Arc;

use crate::stream::{Chunk, ChunkSizer, CostCache, Stream};
use crate::susp::Eval;

/// Strategy for the dense per-block divisibility test.
pub trait BlockSiever: Send + Sync + 'static {
    /// `out[i] == true` iff `candidates[i]` is divisible by **no** element
    /// of `primes`. `primes` entries are all ≥ 2; a candidate equal to a
    /// prime divides itself, so callers pass only primes `< candidate`
    /// (guaranteed here by phase structure: seed primes ≤ √n < block lo).
    fn survivors(&self, candidates: &[u32], primes: &[u32]) -> Vec<bool>;

    /// Diagnostic name for reports.
    fn name(&self) -> &'static str;
}

/// Portable scalar implementation (also the oracle for the kernel).
pub struct RustSiever;

impl BlockSiever for RustSiever {
    fn survivors(&self, candidates: &[u32], primes: &[u32]) -> Vec<bool> {
        candidates
            .iter()
            .map(|&c| primes.iter().all(|&p| c % p != 0))
            .collect()
    }

    fn name(&self) -> &'static str {
        "rust-scalar"
    }
}

/// All primes below `n`, block-granular, generic over the evaluation
/// strategy and the block siever.
pub fn chunked_primes_with_runtime<E: Eval>(
    eval: E,
    n: u32,
    chunk_size: usize,
    siever: Arc<dyn BlockSiever>,
) -> Vec<u32> {
    assert!(chunk_size > 0, "chunk_size must be positive");
    if n <= 2 {
        return Vec::new();
    }

    // Phase 1: sequential seed sieve up to ceil(sqrt(n)) (inclusive).
    let (seed_hi, seed) = seed_primes(n);
    if seed_hi >= n {
        return seed.into_iter().filter(|&p| p < n).collect();
    }
    fan_out_blocks(eval, n, chunk_size, seed_hi, Arc::new(seed), siever)
}

/// Phase 2: independent blocks over `[seed_hi, n)` as a future/lazy
/// stream of chunks — one suspension per block. Returns seed + block
/// survivors in order.
fn fan_out_blocks<E: Eval>(
    eval: E,
    n: u32,
    chunk_size: usize,
    seed_hi: u32,
    seed: Arc<Vec<u32>>,
    siever: Arc<dyn BlockSiever>,
) -> Vec<u32> {
    let blocks: Vec<(u32, u32)> = {
        let mut v = Vec::new();
        let mut lo = seed_hi;
        while lo < n {
            let hi = (lo as u64 + chunk_size as u64).min(n as u64) as u32;
            v.push((lo, hi));
            lo = hi;
        }
        v
    };
    let block_stream: Stream<Chunk<u32>, E> = {
        let seed2 = Arc::clone(&seed);
        let siever2 = Arc::clone(&siever);
        // Captured on the constructing thread (inside the job's cancel
        // scope when run by a coordinator runner); block tasks on pool
        // workers re-check it and return empty once the job is
        // cancelled, so residual fan-out stops burning pool capacity.
        let cancel = crate::susp::cancel::active();
        Stream::from_vec(eval, blocks).map_elems(move |&(lo, hi)| {
            if cancel.as_ref().is_some_and(|c| c.is_cancelled()) {
                return Arc::new(Vec::new());
            }
            let candidates: Vec<u32> = (lo..hi).collect();
            let mask = siever2.survivors(&candidates, &seed2);
            debug_assert_eq!(mask.len(), candidates.len());
            Arc::new(
                candidates
                    .into_iter()
                    .zip(mask)
                    .filter_map(|(c, keep)| keep.then_some(c))
                    .collect::<Vec<u32>>(),
            )
        })
    };

    let mut out: Vec<u32> = (*seed).clone();
    for block in block_stream.iter() {
        out.extend(block.iter().copied());
    }
    out
}

/// Seed phase shared by the fixed and adaptive variants: primes below
/// `ceil(sqrt(n)) + 1` by incremental trial division.
fn seed_primes(n: u32) -> (u32, Vec<u32>) {
    let mut seed_hi = (n as f64).sqrt() as u32 + 1;
    seed_hi = seed_hi.min(n);
    let mut seed: Vec<u32> = Vec::new();
    for c in 2..seed_hi {
        if seed.iter().take_while(|&&p| p * p <= c).all(|&p| c % p != 0) {
            seed.push(c);
        }
    }
    (seed_hi, seed)
}

/// Chunk pick given an already-computed seed: probe the per-candidate
/// cost on a sample block (memoized in `cost` — pass a fresh
/// [`CostCache`] to force a measurement), then let [`ChunkSizer`]
/// balance task grain against worker coverage. Caller guarantees
/// `seed_hi < n`.
fn pick_sieve_chunk(
    n: u32,
    seed_hi: u32,
    seed: &[u32],
    parallelism: usize,
    sizer: &ChunkSizer,
    siever: &dyn BlockSiever,
    cost: &CostCache,
) -> usize {
    let span = (n - seed_hi) as usize;
    let per_candidate = cost.get_or_measure(|| {
        let sample_len = span.min(256).max(1);
        let candidates: Vec<u32> = (seed_hi..seed_hi + sample_len as u32).collect();
        ChunkSizer::probe_cost(sample_len, || {
            std::hint::black_box(siever.survivors(&candidates, seed));
        })
    });
    sizer.pick(per_candidate, span, parallelism)
}

/// Pick the fan-out block size adaptively: probe the per-candidate cost
/// of the seed-prime divisibility test through the *actual* siever (its
/// cost scales with `π(√n)`, so no constant is right for every `n`), then
/// let [`ChunkSizer`] balance task grain against worker coverage.
pub fn adaptive_sieve_chunk(
    n: u32,
    parallelism: usize,
    sizer: &ChunkSizer,
    siever: &dyn BlockSiever,
) -> usize {
    if n <= 2 {
        return sizer.min_chunk.max(1);
    }
    let (seed_hi, seed) = seed_primes(n);
    if seed_hi >= n {
        return sizer.min_chunk.max(1);
    }
    pick_sieve_chunk(n, seed_hi, &seed, parallelism, sizer, siever, &CostCache::new())
}

/// Adaptive chunked sieve: one seed sieve, one probe, one fan-out. (The
/// seed — the Amdahl-bound sequential phase — is computed once and
/// shared between the probe and the fan-out, not recomputed per stage.)
pub fn chunked_primes_adaptive<E: Eval>(
    eval: E,
    n: u32,
    siever: Arc<dyn BlockSiever>,
) -> Vec<u32> {
    chunked_primes_adaptive_cached(eval, n, siever, &CostCache::new())
}

/// [`chunked_primes_adaptive`] with the per-candidate probe memoized in
/// `cost`: the first call through a given cache measures through the
/// real siever, repeated jobs (the coordinator's steady state — each
/// shard keeps one cache per workload) reuse the measurement and skip
/// straight to the fan-out.
pub fn chunked_primes_adaptive_cached<E: Eval>(
    eval: E,
    n: u32,
    siever: Arc<dyn BlockSiever>,
    cost: &CostCache,
) -> Vec<u32> {
    if n <= 2 {
        return Vec::new();
    }
    let (seed_hi, seed) = seed_primes(n);
    if seed_hi >= n {
        return seed.into_iter().filter(|&p| p < n).collect();
    }
    let parallelism = eval.executor().map(|e| e.parallelism()).unwrap_or(1);
    let chunk = pick_sieve_chunk(
        n,
        seed_hi,
        &seed,
        parallelism,
        &ChunkSizer::default(),
        &*siever,
        cost,
    );
    fan_out_blocks(eval, n, chunk, seed_hi, Arc::new(seed), siever)
}

/// [`chunked_primes_with_runtime`] with the portable scalar siever.
pub fn chunked_primes<E: Eval>(eval: E, n: u32, chunk_size: usize) -> Vec<u32> {
    chunked_primes_with_runtime(eval, n, chunk_size, Arc::new(RustSiever))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Executor;
    use crate::sieve::eratosthenes;
    use crate::susp::{FutureEval, LazyEval};

    #[test]
    fn matches_oracle_small() {
        for n in [0, 2, 3, 4, 5, 10, 30, 100] {
            assert_eq!(chunked_primes(LazyEval, n, 8), eratosthenes(n), "n={n}");
        }
    }

    #[test]
    fn matches_oracle_across_chunk_sizes() {
        let oracle = eratosthenes(5000);
        for chunk in [1, 3, 64, 1000, 10_000] {
            assert_eq!(chunked_primes(LazyEval, 5000, chunk), oracle, "chunk={chunk}");
        }
    }

    #[test]
    fn future_strategy_matches_lazy() {
        let oracle = eratosthenes(20_000);
        let ex = Executor::new(4);
        assert_eq!(chunked_primes(FutureEval::new(ex), 20_000, 256), oracle);
    }

    #[test]
    fn par1_matches() {
        let ex = Executor::new(1);
        assert_eq!(chunked_primes(FutureEval::new(ex), 2_000, 64), eratosthenes(2_000));
    }

    #[test]
    fn rust_siever_survivors() {
        let s = RustSiever;
        let mask = s.survivors(&[10, 11, 12, 13], &[2, 3]);
        assert_eq!(mask, vec![false, true, false, true]);
        // No primes: everything survives.
        assert_eq!(s.survivors(&[4, 6], &[]), vec![true, true]);
    }

    #[test]
    fn adaptive_matches_oracle() {
        let oracle = eratosthenes(20_000);
        let got = chunked_primes_adaptive(LazyEval, 20_000, Arc::new(RustSiever));
        assert_eq!(got, oracle);
        let ex = Executor::new(4);
        let got = chunked_primes_adaptive(FutureEval::new(ex), 20_000, Arc::new(RustSiever));
        assert_eq!(got, oracle);
        // Degenerate inputs.
        assert!(chunked_primes_adaptive(LazyEval, 0, Arc::new(RustSiever)).is_empty());
        assert_eq!(chunked_primes_adaptive(LazyEval, 4, Arc::new(RustSiever)), vec![2, 3]);
    }

    #[test]
    fn cached_adaptive_probes_once_and_matches_oracle() {
        use std::sync::atomic::{AtomicUsize, Ordering};

        // Counts survivor calls: job one pays fan-out + 1 probe call,
        // a cached job must pay fan-out only.
        struct CountingSiever(AtomicUsize);
        impl BlockSiever for CountingSiever {
            fn survivors(&self, candidates: &[u32], primes: &[u32]) -> Vec<bool> {
                self.0.fetch_add(1, Ordering::SeqCst);
                RustSiever.survivors(candidates, primes)
            }
            fn name(&self) -> &'static str {
                "counting"
            }
        }

        let oracle = eratosthenes(10_000);
        let cache = crate::stream::CostCache::new();
        let siever = Arc::new(CountingSiever(AtomicUsize::new(0)));
        let got = chunked_primes_adaptive_cached(LazyEval, 10_000, siever.clone(), &cache);
        assert_eq!(got, oracle);
        assert!(cache.get().is_some(), "first job must seed the cache");
        let calls_after_first = siever.0.load(Ordering::SeqCst);
        let got = chunked_primes_adaptive_cached(LazyEval, 10_000, siever.clone(), &cache);
        assert_eq!(got, oracle);
        let calls_second = siever.0.load(Ordering::SeqCst) - calls_after_first;
        // The first job paid fan-out + 1 probe; the second only fan-out.
        assert_eq!(calls_second, calls_after_first - 1, "probe must be cached");
    }

    #[test]
    fn adaptive_chunk_is_positive_and_covered() {
        let sizer = crate::stream::ChunkSizer::default();
        let c = adaptive_sieve_chunk(100_000, 4, &sizer, &RustSiever);
        assert!(c >= 1);
        // Coverage ceiling: no more than span / (par × oversub).
        let span = 100_000 - ((100_000f64).sqrt() as u32 + 1);
        assert!(c <= (span as usize / 16).max(1), "c={c}");
        assert_eq!(adaptive_sieve_chunk(2, 4, &sizer, &RustSiever), 1);
    }

    #[test]
    fn perfect_square_boundary() {
        // n = p^2 edge: largest seed prime must still eliminate p^2.
        let n = 49 * 49; // 2401 = 7^4, sqrt = 49
        assert_eq!(chunked_primes(LazyEval, n, 37), eratosthenes(n));
        let n = 2209; // 47^2
        assert_eq!(chunked_primes(LazyEval, n + 1, 64), eratosthenes(n + 1));
    }
}

//! The paper's first example (§5): a trial-division prime sieve over the
//! monadic stream.
//!
//! ```text
//! def primes = sieve(Stream.range(2, n, 1))
//! def sieve(s: Stream[Int]): Stream[Int] = s match {
//!   case head#::tail =>
//!     head#::tail.map(s => sieve(s.filter { _ % head != 0 }))
//!   case Empty => Empty
//! }
//! ```
//!
//! The paper is explicit that this is *not* an efficient sieve ("it scans
//! every divisor of a number up to the number itself") — it is chosen
//! because each discovered prime adds one more pipeline stage, making it
//! a stress test for task granularity (observation 1: it does not scale,
//! elementary operations are too fine-grained).
//!
//! This module also provides the chunked variant (§7 improvement) and a
//! classical Eratosthenes oracle used by tests and the harness to verify
//! every configuration produces identical primes.

mod chunked;
mod eratosthenes;

pub use chunked::{
    adaptive_sieve_chunk, chunked_primes, chunked_primes_adaptive,
    chunked_primes_adaptive_cached, chunked_primes_with_runtime, BlockSiever, RustSiever,
};
pub use eratosthenes::eratosthenes;

use crate::stream::Stream;
use crate::susp::Eval;

/// The paper's recursive sieve: peel the head (a prime), filter its
/// multiples out of the suspended tail, recurse inside the monad.
pub fn sieve<E: Eval>(s: Stream<u32, E>) -> Stream<u32, E> {
    match s.uncons() {
        None => Stream::Empty,
        Some((head, tail, eval)) => {
            let head = *head;
            let sieved = eval.map(tail, move |t: Stream<u32, E>| {
                sieve(t.filter(move |x| x % head != 0))
            });
            Stream::cons_cell(eval.clone(), head, sieved)
        }
    }
}

/// `primes` / `primes_x3`: all primes below `n`, via [`sieve`] over
/// `Stream.range(2, n, 1)`. The strategy decides seq vs par — the same
/// code runs both (the paper's central claim).
pub fn primes_stream<E: Eval>(eval: E, n: u32) -> Stream<u32, E> {
    sieve(Stream::range(eval, 2, n))
}

/// Convenience: run the sieve to completion (the paper's
/// `primes.force`) and collect.
pub fn primes<E: Eval>(eval: E, n: u32) -> Vec<u32> {
    primes_stream(eval, n).to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::Executor;
    use crate::susp::{FutureEval, LazyEval, StrictEval};

    const PRIMES_TO_50: &[u32] = &[2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47];

    #[test]
    fn lazy_sieve_matches_known_primes() {
        assert_eq!(primes(LazyEval, 50), PRIMES_TO_50);
    }

    #[test]
    fn strict_sieve_matches() {
        assert_eq!(primes(StrictEval, 50), PRIMES_TO_50);
    }

    #[test]
    fn future_sieve_matches_par2() {
        let ex = Executor::new(2);
        assert_eq!(primes(FutureEval::new(ex), 50), PRIMES_TO_50);
    }

    #[test]
    fn future_sieve_matches_par1() {
        // The paper's par(1): all overhead, no parallelism, same result.
        let ex = Executor::new(1);
        assert_eq!(primes(FutureEval::new(ex), 50), PRIMES_TO_50);
    }

    #[test]
    fn all_strategies_agree_with_eratosthenes_1000() {
        let oracle = eratosthenes(1000);
        assert_eq!(primes(LazyEval, 1000), oracle);
        let ex = Executor::new(4);
        assert_eq!(primes(FutureEval::new(ex), 1000), oracle);
    }

    #[test]
    fn empty_and_tiny_ranges() {
        assert!(primes(LazyEval, 2).is_empty());
        assert_eq!(primes(LazyEval, 3), vec![2]);
        assert_eq!(primes(LazyEval, 4), vec![2, 3]);
    }

    #[test]
    fn prime_count_at_20000_matches_pi() {
        // π(20000) = 2262 — the paper's primes workload size.
        // (Run on the Lazy strategy; the Future variant is exercised at
        // smaller n above and at full size in the benches. Deep filter
        // chains need a big stack — same as the CLI's driver thread.)
        let got = crate::testkit::with_stack(512, || primes(LazyEval, 20_000));
        assert_eq!(got.len(), 2262);
        assert_eq!(*got.last().unwrap(), 19_997);
    }

    #[test]
    fn sieve_stream_is_incremental_under_lazy() {
        // Asking for the first few primes must not force the whole range.
        let s = primes_stream(LazyEval, 1_000_000);
        assert_eq!(s.take(5).to_vec(), vec![2, 3, 5, 7, 11]);
    }
}

//! Run configuration: a typed config struct, a TOML-subset parser (no
//! serde offline), CLI-flag overlay, and validation.
//!
//! Precedence, lowest to highest: defaults < config file < `--set k=v`
//! CLI overrides. Everything the benches and the coordinator vary
//! (parallelism, workload sizes, chunking, artifact paths) lives here so
//! experiments are reproducible from a single file.

mod parser;

pub use parser::{parse_toml_subset, TomlValue};

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::exec::DequeKind;

/// Evaluation mode requested for a run: the paper's seq / par(n) axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Lazy suspensions (the paper's `seq` column).
    Seq,
    /// Future suspensions on an n-worker pool (`par(n)`).
    Par(usize),
    /// Strict evaluation (control; not in the paper's table).
    Strict,
}

impl Mode {
    pub fn label(&self) -> String {
        match self {
            Mode::Seq => "seq".to_string(),
            Mode::Par(n) => format!("par({n})"),
            Mode::Strict => "strict".to_string(),
        }
    }

    pub fn parse(s: &str) -> Result<Mode, ConfigError> {
        if s == "seq" {
            return Ok(Mode::Seq);
        }
        if s == "strict" {
            return Ok(Mode::Strict);
        }
        if let Some(inner) = s.strip_prefix("par(").and_then(|r| r.strip_suffix(')')) {
            let n: usize = inner
                .parse()
                .map_err(|_| ConfigError::new(format!("bad parallelism in mode: {s}")))?;
            if n == 0 {
                return Err(ConfigError::new("par(0) is not a mode"));
            }
            return Ok(Mode::Par(n));
        }
        if let Some(n) = s.strip_prefix("par") {
            // Accept "par2" shorthand.
            if let Ok(n) = n.parse::<usize>() {
                if n > 0 {
                    return Ok(Mode::Par(n));
                }
            }
        }
        Err(ConfigError::new(format!("unknown mode: {s} (want seq | strict | par(N))")))
    }
}

/// How the chunked workloads pick their block edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkPolicy {
    /// Measure the per-element cost and size blocks from it
    /// ([`crate::stream::ChunkSizer`]); the measured cost is cached per
    /// workload inside the owning coordinator shard. The default.
    Adaptive,
    /// Use `chunk_size` verbatim — the pre-sharding behaviour, kept for
    /// A/B runs (the A1 chunk-sweep ablation pins this).
    Fixed,
}

impl ChunkPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            ChunkPolicy::Adaptive => "adaptive",
            ChunkPolicy::Fixed => "fixed",
        }
    }
}

impl std::str::FromStr for ChunkPolicy {
    type Err = ConfigError;

    fn from_str(s: &str) -> Result<ChunkPolicy, ConfigError> {
        match s.trim() {
            "adaptive" => Ok(ChunkPolicy::Adaptive),
            "fixed" => Ok(ChunkPolicy::Fixed),
            other => Err(ConfigError::new(format!(
                "unknown chunk policy: {other} (want adaptive | fixed)"
            ))),
        }
    }
}

/// What `Pipeline::submit` does when the bounded admission queue is full
/// (the ingress backpressure policy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Block the submitter until a slot frees (synchronous callers keep
    /// their pre-ingress semantics). The default.
    Block,
    /// Reject immediately with a shed error — load-shedding front doors
    /// that prefer fast failure over queueing.
    Shed,
    /// Wait up to the given number of milliseconds for a slot, then shed.
    /// A timed-out submission leaves no residue: the slot it waited for
    /// stays with the queue.
    Timeout(u64),
}

impl AdmissionPolicy {
    pub fn label(&self) -> String {
        match self {
            AdmissionPolicy::Block => "block".to_string(),
            AdmissionPolicy::Shed => "shed".to_string(),
            AdmissionPolicy::Timeout(ms) => format!("timeout({ms})"),
        }
    }
}

impl std::str::FromStr for AdmissionPolicy {
    type Err = ConfigError;

    fn from_str(s: &str) -> Result<AdmissionPolicy, ConfigError> {
        let s = s.trim();
        match s {
            "block" => return Ok(AdmissionPolicy::Block),
            "shed" => return Ok(AdmissionPolicy::Shed),
            _ => {}
        }
        if let Some(inner) = s.strip_prefix("timeout(").and_then(|r| r.strip_suffix(')')) {
            let inner = inner.trim().trim_end_matches("ms").trim();
            let ms: u64 = inner.parse().map_err(|_| {
                ConfigError::new(format!("bad timeout in admission policy: {s}"))
            })?;
            if ms == 0 {
                return Err(ConfigError::new("timeout(0) is not an admission policy"));
            }
            return Ok(AdmissionPolicy::Timeout(ms));
        }
        Err(ConfigError::new(format!(
            "unknown admission policy: {s} (want block | shed | timeout(MS))"
        )))
    }
}

/// Which wire protocol a TCP listener speaks (see the coordinator
/// module docs, "Wire protocol").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireProtocol {
    /// Length-prefixed binary frames on a poll(2) reactor — the
    /// event-loop ingress.
    Framed,
    /// Newline-delimited text commands, one blocking thread per
    /// session. The compatibility baseline and A/B control; the
    /// default.
    Text,
}

impl WireProtocol {
    pub fn label(&self) -> &'static str {
        match self {
            WireProtocol::Framed => "framed",
            WireProtocol::Text => "text",
        }
    }

    /// Parse `SFUT_WIRE` if set. Panics on an invalid value: CI pins
    /// the wire mode per step, and a typo silently falling back to the
    /// default would invalidate the A/B comparison.
    pub fn from_env() -> Option<WireProtocol> {
        let raw = std::env::var("SFUT_WIRE").ok()?;
        match raw.parse() {
            Ok(kind) => Some(kind),
            Err(e) => panic!("SFUT_WIRE: {e}"),
        }
    }

    /// Env override if present, otherwise [`WireProtocol::Text`].
    pub fn default_wire() -> WireProtocol {
        WireProtocol::from_env().unwrap_or(WireProtocol::Text)
    }
}

impl std::str::FromStr for WireProtocol {
    type Err = ConfigError;

    fn from_str(s: &str) -> Result<WireProtocol, ConfigError> {
        match s.trim() {
            "framed" | "frame" | "binary" => Ok(WireProtocol::Framed),
            "text" | "line" => Ok(WireProtocol::Text),
            other => Err(ConfigError::new(format!(
                "unknown wire protocol: {other} (want framed | text)"
            ))),
        }
    }
}

/// Readiness backend the framed reactor pool polls descriptors with
/// (see the coordinator module docs, "Wire protocol").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PollerKind {
    /// Pick the best backend for the platform: epoll on Linux, the
    /// poll(2) scan everywhere else. The default.
    Auto,
    /// The portable poll(2) descriptor scan — O(n) per wakeup, kept as
    /// the A/B baseline the epoll backend is measured against.
    Poll,
    /// Linux epoll (`epoll_create1`/`epoll_ctl`/`epoll_wait`): O(1)
    /// readiness delivery regardless of session count. Selecting it on
    /// a non-Linux platform fails at listener start.
    Epoll,
}

impl PollerKind {
    pub fn label(&self) -> &'static str {
        match self {
            PollerKind::Auto => "auto",
            PollerKind::Poll => "poll",
            PollerKind::Epoll => "epoll",
        }
    }

    /// Parse `SFUT_POLLER` if set. Panics on an invalid value: CI pins
    /// the backend per step, and a typo silently falling back to the
    /// default would invalidate the poll-vs-epoll A/B comparison.
    pub fn from_env() -> Option<PollerKind> {
        let raw = std::env::var("SFUT_POLLER").ok()?;
        match raw.parse() {
            Ok(kind) => Some(kind),
            Err(e) => panic!("SFUT_POLLER: {e}"),
        }
    }

    /// Env override if present, otherwise [`PollerKind::Auto`].
    pub fn default_poller() -> PollerKind {
        PollerKind::from_env().unwrap_or(PollerKind::Auto)
    }

    /// The concrete backend `Auto` resolves to on this platform.
    pub fn resolved(&self) -> PollerKind {
        match self {
            PollerKind::Auto => {
                if cfg!(target_os = "linux") {
                    PollerKind::Epoll
                } else {
                    PollerKind::Poll
                }
            }
            other => *other,
        }
    }
}

impl std::str::FromStr for PollerKind {
    type Err = ConfigError;

    fn from_str(s: &str) -> Result<PollerKind, ConfigError> {
        match s.trim() {
            "auto" => Ok(PollerKind::Auto),
            "poll" => Ok(PollerKind::Poll),
            "epoll" => Ok(PollerKind::Epoll),
            other => Err(ConfigError::new(format!(
                "unknown poller: {other} (want poll | epoll | auto)"
            ))),
        }
    }
}

/// Parse `SFUT_REACTORS` if set (the framed reactor-thread count; 0 =
/// auto from cores). Panics on an invalid value for the same reason as
/// [`PollerKind::from_env`].
pub fn reactors_from_env() -> Option<usize> {
    let raw = std::env::var("SFUT_REACTORS").ok()?;
    match raw.trim().parse() {
        Ok(n) => Some(n),
        Err(_) => panic!("SFUT_REACTORS: not a reactor count: {raw}"),
    }
}

// NOTE: the closed `Workload` enum that used to live here is gone.
// Workloads are an open set now: `workload::StreamWorkload` plugins
// registered in a `workload::WorkloadRegistry`, resolved by *name* at
// submit time. Config stays workload-agnostic — per-scenario knobs
// travel as request params (`workload(k=v,...)`).

/// Full run configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    /// Primes workload bound (the paper: 20000; primes_x3 uses 3×).
    pub primes_n: u32,
    /// Fateman base polynomial: (1 + x + y + z + t)^k. The paper (via
    /// Fateman's benchmark) uses degree 20 on 4 variables; k is the
    /// scaling knob.
    pub fateman_vars: usize,
    pub fateman_degree: u32,
    /// Big-coefficient factor (paper: 100000000001).
    pub big_factor: i64,
    /// Block size for the chunked variants (§7 improvement). Only
    /// binding under [`ChunkPolicy::Fixed`]; the adaptive policy derives
    /// the edge from a measured per-element cost.
    pub chunk_size: usize,
    /// How chunked workloads pick their block edge.
    pub chunk_policy: ChunkPolicy,
    /// Coordinator shards (independent executor-pool groups). 0 = auto:
    /// physical cores / `shard_parallelism`, at least 1.
    pub shards: usize,
    /// Nominal per-shard parallelism; sizes the auto shard count and the
    /// ingress runner count per shard (concurrent jobs a shard executes).
    pub shard_parallelism: usize,
    /// Bound on jobs admitted but not yet executing (the ingress
    /// admission queue plus the per-shard run queues). The backpressure
    /// knob: when this many jobs are waiting, `admission` decides.
    pub queue_depth: usize,
    /// What `Pipeline::submit` does when `queue_depth` is reached.
    pub admission: AdmissionPolicy,
    /// Ingress dispatcher threads (admission queue → shard run queues).
    pub dispatchers: usize,
    /// A backed-up shard's run-queue depth must *exceed* this before
    /// idle shards steal whole queued jobs from it (cross-shard
    /// migration; 1 = steal once two or more jobs are waiting).
    pub migrate_threshold: usize,
    /// Default per-job execution deadline in milliseconds, enforced by
    /// the shard-set reaper through cooperative cancellation. 0 (the
    /// default) disables deadlines; a request overrides per job with
    /// the reserved `deadline_ms` param.
    pub deadline_ms: u64,
    /// Retries a job gets after a *transient* failure (panic or
    /// deadline timeout — never a validation reject or workload error),
    /// each re-leased onto a different shard. 0 (the default) disables
    /// retry.
    pub retry_max: u32,
    /// Base backoff before a retry, in milliseconds; attempt `k` waits
    /// `retry_backoff_ms << k` (capped at 5s).
    pub retry_backoff_ms: u64,
    /// Consecutive panics of one workload that trip its circuit
    /// breaker: further submissions answer `err rejected … breaker
    /// open` without taking queue capacity. 0 (the default) disables
    /// the breaker.
    pub breaker_threshold: u32,
    /// Directory holding AOT artifacts (*.hlo.txt).
    pub artifacts_dir: PathBuf,
    /// Use the PJRT kernel for chunked block products when artifacts are
    /// present.
    pub use_kernel: bool,
    /// Worker stack size (deep recursion in stream forcing).
    pub stack_size: usize,
    /// Per-worker deque implementation for every executor pool the
    /// coordinator builds: `chase_lev` (lock-free ring, default) or
    /// `locked` (the mutexed A/B baseline). Overridable via the
    /// `deque`/`exec.deque` config key, `--deque`, or `SFUT_DEQUE`.
    pub deque: DequeKind,
    /// Wire protocol TCP listeners speak: `framed` (binary frames on a
    /// poll reactor) or `text` (newline commands, thread per session,
    /// the default). Overridable via the `wire`/`ingress.wire` config
    /// key, `--wire`, or `SFUT_WIRE`.
    pub wire: WireProtocol,
    /// Readiness backend for framed listeners: `poll` (portable O(n)
    /// scan, the A/B baseline), `epoll` (Linux, O(1) delivery), or
    /// `auto` (epoll where available; the default). Overridable via the
    /// `poller`/`ingress.poller` config key, `--poller`, or
    /// `SFUT_POLLER`.
    pub poller: PollerKind,
    /// Reactor threads a framed listener runs (accepts fan out
    /// round-robin; each session is pinned to one reactor for life).
    /// 0 = auto from available cores; 1 (the default) keeps the PR 7
    /// single-reactor shape. Overridable via `reactors`/
    /// `ingress.reactors`, `--reactors`, or `SFUT_REACTORS`.
    pub reactors: usize,
    /// Whether a multi-reactor framed listener may bind an
    /// SO_REUSEPORT listener group (kernel-hashed accept fanout).
    /// `false` forces the in-process fd-handoff path, whose round-robin
    /// dispatch is deterministic — the fanout tests pin it. Overridable
    /// via `reuseport`/`ingress.reuseport`.
    pub reuseport: bool,
    /// Bench harness: measurement samples per cell.
    pub samples: usize,
    /// Bench harness: warmup iterations per cell.
    pub warmup: usize,
    /// Scale factor applied to workload sizes (1.0 = paper scale).
    pub scale: f64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            primes_n: 20_000,
            fateman_vars: 4,
            fateman_degree: 12,
            big_factor: 100_000_000_001,
            chunk_size: 64,
            chunk_policy: ChunkPolicy::Adaptive,
            shards: 0,
            shard_parallelism: 2,
            queue_depth: 64,
            admission: AdmissionPolicy::Block,
            dispatchers: 2,
            migrate_threshold: 1,
            deadline_ms: 0,
            retry_max: 0,
            retry_backoff_ms: 25,
            breaker_threshold: 0,
            artifacts_dir: PathBuf::from("artifacts"),
            use_kernel: true,
            stack_size: 256 << 20,
            deque: DequeKind::default_kind(),
            wire: WireProtocol::default_wire(),
            poller: PollerKind::default_poller(),
            reactors: reactors_from_env().unwrap_or(1),
            reuseport: true,
            samples: 5,
            warmup: 1,
            scale: 1.0,
        }
    }
}

/// Configuration error with a message and optional source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigError {
    pub message: String,
    pub line: Option<usize>,
}

impl ConfigError {
    pub fn new(message: impl Into<String>) -> Self {
        ConfigError { message: message.into(), line: None }
    }

    pub fn at(message: impl Into<String>, line: usize) -> Self {
        ConfigError { message: message.into(), line: Some(line) }
    }
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.line {
            Some(l) => write!(f, "config error at line {l}: {}", self.message),
            None => write!(f, "config error: {}", self.message),
        }
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    /// Load from a TOML-subset file, then apply `key=value` overrides.
    pub fn load(
        path: Option<&std::path::Path>,
        overrides: &[(String, String)],
    ) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        if let Some(path) = path {
            let text = std::fs::read_to_string(path)
                .map_err(|e| ConfigError::new(format!("cannot read {}: {e}", path.display())))?;
            let values = parse_toml_subset(&text)?;
            cfg.apply_values(&values)?;
        }
        for (k, v) in overrides {
            cfg.set(k, v)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    fn apply_values(&mut self, values: &BTreeMap<String, TomlValue>) -> Result<(), ConfigError> {
        for (k, v) in values {
            self.set(k, &v.as_raw_string())?;
        }
        Ok(())
    }

    /// Set a single dotted key. Unknown keys are errors — typos in
    /// experiment configs must not silently run the default.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), ConfigError> {
        fn p<T: std::str::FromStr>(key: &str, v: &str) -> Result<T, ConfigError> {
            v.trim().parse().map_err(|_| ConfigError::new(format!("bad value for {key}: {v}")))
        }
        match key {
            "primes_n" | "primes.n" => self.primes_n = p(key, value)?,
            "fateman_vars" | "fateman.vars" => self.fateman_vars = p(key, value)?,
            "fateman_degree" | "fateman.degree" => self.fateman_degree = p(key, value)?,
            "big_factor" | "fateman.big_factor" => self.big_factor = p(key, value)?,
            "chunk_size" | "chunked.size" => self.chunk_size = p(key, value)?,
            "chunk_policy" | "chunked.policy" => self.chunk_policy = p(key, value)?,
            "shards" | "coordinator.shards" => self.shards = p(key, value)?,
            "shard_parallelism" | "coordinator.shard_parallelism" => {
                self.shard_parallelism = p(key, value)?;
            }
            "queue_depth" | "ingress.queue_depth" => self.queue_depth = p(key, value)?,
            "admission" | "ingress.admission" => self.admission = p(key, value)?,
            "dispatchers" | "ingress.dispatchers" => self.dispatchers = p(key, value)?,
            "migrate_threshold" | "ingress.migrate_threshold" => {
                self.migrate_threshold = p(key, value)?;
            }
            "deadline_ms" | "ingress.deadline_ms" => self.deadline_ms = p(key, value)?,
            "retry_max" | "ingress.retry_max" => self.retry_max = p(key, value)?,
            "retry_backoff_ms" | "ingress.retry_backoff_ms" => {
                self.retry_backoff_ms = p(key, value)?;
            }
            "breaker_threshold" | "ingress.breaker_threshold" => {
                self.breaker_threshold = p(key, value)?;
            }
            "artifacts_dir" | "runtime.artifacts_dir" => {
                self.artifacts_dir = PathBuf::from(value.trim().trim_matches('"'));
            }
            "use_kernel" | "runtime.use_kernel" => self.use_kernel = p(key, value)?,
            "stack_size" | "exec.stack_size" => self.stack_size = p(key, value)?,
            "deque" | "exec.deque" => self.deque = p(key, value)?,
            "wire" | "ingress.wire" => self.wire = p(key, value)?,
            "poller" | "ingress.poller" => self.poller = p(key, value)?,
            "reactors" | "ingress.reactors" => self.reactors = p(key, value)?,
            "reuseport" | "ingress.reuseport" => self.reuseport = p(key, value)?,
            "samples" | "bench.samples" => self.samples = p(key, value)?,
            "warmup" | "bench.warmup" => self.warmup = p(key, value)?,
            "scale" | "bench.scale" => self.scale = p(key, value)?,
            _ => return Err(ConfigError::new(format!("unknown config key: {key}"))),
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.primes_n < 3 {
            return Err(ConfigError::new("primes_n must be >= 3"));
        }
        if self.fateman_vars == 0 || self.fateman_vars > 8 {
            return Err(ConfigError::new("fateman_vars must be in 1..=8"));
        }
        if self.fateman_degree == 0 {
            return Err(ConfigError::new("fateman_degree must be >= 1"));
        }
        if self.chunk_size == 0 {
            return Err(ConfigError::new("chunk_size must be >= 1"));
        }
        if self.shards > 256 {
            return Err(ConfigError::new("shards must be <= 256 (0 = auto)"));
        }
        if self.shard_parallelism == 0 {
            return Err(ConfigError::new("shard_parallelism must be >= 1"));
        }
        if self.queue_depth == 0 {
            return Err(ConfigError::new("queue_depth must be >= 1"));
        }
        if self.dispatchers == 0 || self.dispatchers > 64 {
            return Err(ConfigError::new("dispatchers must be in 1..=64"));
        }
        if self.migrate_threshold == 0 {
            return Err(ConfigError::new("migrate_threshold must be >= 1"));
        }
        if self.retry_max > 8 {
            return Err(ConfigError::new("retry_max must be <= 8 (0 = off)"));
        }
        if self.retry_backoff_ms == 0 || self.retry_backoff_ms > 60_000 {
            return Err(ConfigError::new("retry_backoff_ms must be in 1..=60000"));
        }
        if self.deadline_ms > 86_400_000 {
            return Err(ConfigError::new("deadline_ms must be <= 86400000 (0 = off)"));
        }
        if self.reactors > 128 {
            return Err(ConfigError::new("reactors must be <= 128 (0 = auto)"));
        }
        if self.samples == 0 {
            return Err(ConfigError::new("samples must be >= 1"));
        }
        if !(self.scale > 0.0) {
            return Err(ConfigError::new("scale must be > 0"));
        }
        Ok(())
    }

    /// Effective primes bound after `scale`.
    pub fn scaled_primes_n(&self) -> u32 {
        ((self.primes_n as f64 * self.scale) as u32).max(3)
    }

    /// Effective Fateman degree after `scale` (cube-root-ish damping:
    /// term count grows ~degree^vars).
    pub fn scaled_fateman_degree(&self) -> u32 {
        ((self.fateman_degree as f64 * self.scale.powf(0.5)) as u32).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        assert_eq!(Mode::parse("seq").unwrap(), Mode::Seq);
        assert_eq!(Mode::parse("strict").unwrap(), Mode::Strict);
        assert_eq!(Mode::parse("par(2)").unwrap(), Mode::Par(2));
        assert_eq!(Mode::parse("par4").unwrap(), Mode::Par(4));
        assert!(Mode::parse("par(0)").is_err());
        assert!(Mode::parse("warp").is_err());
        assert_eq!(Mode::Par(2).label(), "par(2)");
    }

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn set_unknown_key_fails() {
        let mut c = Config::default();
        assert!(c.set("primes_m", "10").is_err());
    }

    #[test]
    fn overrides_apply_in_order() {
        let cfg = Config::load(
            None,
            &[
                ("primes_n".to_string(), "500".to_string()),
                ("primes_n".to_string(), "700".to_string()),
                ("chunk_size".to_string(), "16".to_string()),
            ],
        )
        .unwrap();
        assert_eq!(cfg.primes_n, 700);
        assert_eq!(cfg.chunk_size, 16);
    }

    #[test]
    fn bad_values_are_reported() {
        let mut c = Config::default();
        let err = c.set("primes_n", "many").unwrap_err();
        assert!(err.message.contains("primes_n"));
    }

    #[test]
    fn validation_rejects_nonsense() {
        let mut c = Config::default();
        c.primes_n = 1;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.scale = 0.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.shard_parallelism = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.shards = 1000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn sharding_and_chunk_policy_keys_parse() {
        let mut c = Config::default();
        c.set("shards", "4").unwrap();
        c.set("coordinator.shard_parallelism", "3").unwrap();
        c.set("chunk_policy", "fixed").unwrap();
        assert_eq!(c.shards, 4);
        assert_eq!(c.shard_parallelism, 3);
        assert_eq!(c.chunk_policy, ChunkPolicy::Fixed);
        c.set("chunked.policy", "adaptive").unwrap();
        assert_eq!(c.chunk_policy, ChunkPolicy::Adaptive);
        assert!(c.set("chunk_policy", "random").is_err());
        assert_eq!(ChunkPolicy::Adaptive.label(), "adaptive");
        assert_eq!("fixed".parse::<ChunkPolicy>().unwrap(), ChunkPolicy::Fixed);
    }

    #[test]
    fn deque_kind_keys_parse() {
        let mut c = Config::default();
        c.set("deque", "locked").unwrap();
        assert_eq!(c.deque, DequeKind::Locked);
        c.set("exec.deque", "chase_lev").unwrap();
        assert_eq!(c.deque, DequeKind::ChaseLev);
        assert!(c.set("deque", "spinlock").is_err());
        c.validate().unwrap();
    }

    #[test]
    fn wire_protocol_keys_parse() {
        let mut c = Config::default();
        assert_eq!(c.wire, WireProtocol::Text, "text wire is the compat default");
        c.set("wire", "framed").unwrap();
        assert_eq!(c.wire, WireProtocol::Framed);
        c.set("ingress.wire", "text").unwrap();
        assert_eq!(c.wire, WireProtocol::Text);
        assert!(c.set("wire", "carrier_pigeon").is_err());
        assert_eq!(WireProtocol::Framed.label(), "framed");
        assert_eq!("binary".parse::<WireProtocol>().unwrap(), WireProtocol::Framed);
        assert_eq!("line".parse::<WireProtocol>().unwrap(), WireProtocol::Text);
        c.validate().unwrap();
    }

    #[test]
    fn poller_and_reactor_keys_parse() {
        let mut c = Config::default();
        if std::env::var("SFUT_POLLER").is_err() {
            assert_eq!(c.poller, PollerKind::Auto, "auto poller is the default");
        }
        if std::env::var("SFUT_REACTORS").is_err() {
            assert_eq!(c.reactors, 1, "single reactor is the default shape");
        }
        assert!(c.reuseport, "reuseport fanout defaults on");
        c.set("poller", "epoll").unwrap();
        assert_eq!(c.poller, PollerKind::Epoll);
        c.set("ingress.poller", "poll").unwrap();
        assert_eq!(c.poller, PollerKind::Poll);
        assert!(c.set("poller", "kqueue").is_err());
        c.set("reactors", "4").unwrap();
        assert_eq!(c.reactors, 4);
        c.set("ingress.reactors", "0").unwrap();
        assert_eq!(c.reactors, 0, "0 = auto from cores");
        c.set("reuseport", "false").unwrap();
        assert!(!c.reuseport);
        c.validate().unwrap();
        let mut c = Config::default();
        c.reactors = 129;
        assert!(c.validate().is_err());
        assert_eq!(PollerKind::Epoll.label(), "epoll");
        assert_eq!("auto".parse::<PollerKind>().unwrap(), PollerKind::Auto);
        assert_eq!(PollerKind::Poll.resolved(), PollerKind::Poll);
        if cfg!(target_os = "linux") {
            assert_eq!(PollerKind::Auto.resolved(), PollerKind::Epoll);
        } else {
            assert_eq!(PollerKind::Auto.resolved(), PollerKind::Poll);
        }
    }

    #[test]
    fn admission_policy_parses_and_labels() {
        assert_eq!("block".parse::<AdmissionPolicy>().unwrap(), AdmissionPolicy::Block);
        assert_eq!("shed".parse::<AdmissionPolicy>().unwrap(), AdmissionPolicy::Shed);
        assert_eq!(
            "timeout(250)".parse::<AdmissionPolicy>().unwrap(),
            AdmissionPolicy::Timeout(250)
        );
        assert_eq!(
            "timeout(250ms)".parse::<AdmissionPolicy>().unwrap(),
            AdmissionPolicy::Timeout(250)
        );
        assert!("timeout(0)".parse::<AdmissionPolicy>().is_err());
        assert!("drop".parse::<AdmissionPolicy>().is_err());
        assert_eq!(AdmissionPolicy::Timeout(50).label(), "timeout(50)");
        assert_eq!(AdmissionPolicy::Block.label(), "block");
    }

    #[test]
    fn ingress_keys_parse_and_validate() {
        let mut c = Config::default();
        c.set("queue_depth", "8").unwrap();
        c.set("ingress.admission", "timeout(100)").unwrap();
        c.set("dispatchers", "3").unwrap();
        c.set("ingress.migrate_threshold", "2").unwrap();
        assert_eq!(c.queue_depth, 8);
        assert_eq!(c.admission, AdmissionPolicy::Timeout(100));
        assert_eq!(c.dispatchers, 3);
        assert_eq!(c.migrate_threshold, 2);
        c.validate().unwrap();
        assert!(c.set("admission", "random").is_err());
        let mut c = Config::default();
        c.queue_depth = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.dispatchers = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.migrate_threshold = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn lifecycle_keys_parse_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.deadline_ms, 0, "deadlines default off");
        assert_eq!(c.retry_max, 0, "retry defaults off");
        assert_eq!(c.breaker_threshold, 0, "breaker defaults off");
        c.set("deadline_ms", "250").unwrap();
        c.set("ingress.retry_max", "2").unwrap();
        c.set("retry_backoff_ms", "5").unwrap();
        c.set("ingress.breaker_threshold", "3").unwrap();
        assert_eq!(c.deadline_ms, 250);
        assert_eq!(c.retry_max, 2);
        assert_eq!(c.retry_backoff_ms, 5);
        assert_eq!(c.breaker_threshold, 3);
        c.validate().unwrap();
        assert!(c.set("retry_max", "some").is_err());
        let mut c = Config::default();
        c.retry_max = 9;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.retry_backoff_ms = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn load_from_file() {
        let dir = std::env::temp_dir().join("sfut-config-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.toml");
        std::fs::write(
            &path,
            "# experiment\nprimes_n = 1234\nuse_kernel = false\nscale = 0.5\n",
        )
        .unwrap();
        let cfg = Config::load(Some(&path), &[]).unwrap();
        assert_eq!(cfg.primes_n, 1234);
        assert!(!cfg.use_kernel);
        assert_eq!(cfg.scale, 0.5);
        assert_eq!(cfg.scaled_primes_n(), 617);
    }
}

//! Minimal TOML-subset parser.
//!
//! Supports exactly what experiment configs need (serde/toml are not
//! available offline):
//!
//! * `key = value` pairs; values: integers, floats, booleans, quoted
//!   strings;
//! * `[section]` headers (keys become `section.key`);
//! * `#` comments and blank lines.
//!
//! Arrays, inline tables, multi-line strings and datetimes are rejected
//! with a line-numbered error rather than mis-parsed.

use std::collections::BTreeMap;

use super::ConfigError;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

impl TomlValue {
    /// The raw string form fed into `Config::set` (strings unquoted).
    pub fn as_raw_string(&self) -> String {
        match self {
            TomlValue::Int(v) => v.to_string(),
            TomlValue::Float(v) => v.to_string(),
            TomlValue::Bool(v) => v.to_string(),
            TomlValue::Str(v) => v.clone(),
        }
    }
}

/// Parse `text`; keys inside `[section]` are returned as `section.key`.
pub fn parse_toml_subset(text: &str) -> Result<BTreeMap<String, TomlValue>, ConfigError> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| ConfigError::at("unterminated [section]", lineno))?
                .trim();
            if name.is_empty() || !name.chars().all(is_key_char) {
                return Err(ConfigError::at(format!("bad section name: {name}"), lineno));
            }
            section = name.to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| ConfigError::at(format!("expected key = value, got: {line}"), lineno))?;
        let key = key.trim();
        if key.is_empty() || !key.chars().all(is_key_char) {
            return Err(ConfigError::at(format!("bad key: {key}"), lineno));
        }
        let full_key =
            if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        let value = parse_value(value.trim(), lineno)?;
        if out.insert(full_key.clone(), value).is_some() {
            return Err(ConfigError::at(format!("duplicate key: {full_key}"), lineno));
        }
    }
    Ok(out)
}

fn is_key_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.'
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string is content, not a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue, ConfigError> {
    if s.is_empty() {
        return Err(ConfigError::at("missing value", lineno));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| ConfigError::at("unterminated string", lineno))?;
        if inner.contains('"') {
            return Err(ConfigError::at("embedded quote in string", lineno));
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if s.starts_with('[') || s.starts_with('{') {
        return Err(ConfigError::at("arrays/tables are not supported", lineno));
    }
    let cleaned = s.replace('_', "");
    if let Ok(v) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(v));
    }
    if let Ok(v) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    Err(ConfigError::at(format!("cannot parse value: {s}"), lineno))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let m = parse_toml_subset(
            "a = 1\nb = -2.5\nc = true\nd = \"hi\"\nbig = 100_000_000_001\n",
        )
        .unwrap();
        assert_eq!(m["a"], TomlValue::Int(1));
        assert_eq!(m["b"], TomlValue::Float(-2.5));
        assert_eq!(m["c"], TomlValue::Bool(true));
        assert_eq!(m["d"], TomlValue::Str("hi".to_string()));
        assert_eq!(m["big"], TomlValue::Int(100_000_000_001));
    }

    #[test]
    fn sections_prefix_keys() {
        let m = parse_toml_subset("[bench]\nsamples = 3\n[exec]\nstack_size = 1024\n").unwrap();
        assert_eq!(m["bench.samples"], TomlValue::Int(3));
        assert_eq!(m["exec.stack_size"], TomlValue::Int(1024));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let m = parse_toml_subset("# header\n\na = 1 # trailing\ns = \"a # not comment\"\n")
            .unwrap();
        assert_eq!(m["a"], TomlValue::Int(1));
        assert_eq!(m["s"], TomlValue::Str("a # not comment".to_string()));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_toml_subset("a = 1\nwhat\n").unwrap_err();
        assert_eq!(err.line, Some(2));
        let err = parse_toml_subset("x = [1,2]\n").unwrap_err();
        assert!(err.message.contains("not supported"));
    }

    #[test]
    fn duplicate_keys_rejected() {
        let err = parse_toml_subset("a = 1\na = 2\n").unwrap_err();
        assert!(err.message.contains("duplicate"));
    }

    #[test]
    fn bad_section_rejected() {
        assert!(parse_toml_subset("[bad\n").is_err());
        assert!(parse_toml_subset("[]\n").is_err());
    }

    #[test]
    fn unterminated_string_rejected() {
        assert!(parse_toml_subset("s = \"oops\n").is_err());
    }
}

//! Exact rational numbers over [`BigInt`] — the coefficient field the
//! Gröbner application runs on.
//!
//! Floating-point Buchberger is numerically unstable: terms that should
//! cancel exactly leave ~1e-17 residues which then masquerade as new
//! leading terms and corrupt the basis (observed directly in this repo's
//! first f64 attempt — see EXPERIMENTS.md). `Rational` keeps every
//! reduction exact.
//!
//! Representation: `num / den`, always normalized — `den > 0`,
//! `gcd(|num|, den) = 1`, and zero is `0/1`.

use crate::bigint::BigInt;
use crate::poly::{Coeff, FieldCoeff};

/// An exact rational number.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Rational {
    num: BigInt,
    den: BigInt, // invariant: positive
}

impl Rational {
    pub fn zero() -> Self {
        Rational { num: BigInt::zero(), den: BigInt::one() }
    }

    pub fn one() -> Self {
        Rational { num: BigInt::one(), den: BigInt::one() }
    }

    /// Build and normalize. Panics if `den` is zero.
    pub fn new(num: BigInt, den: BigInt) -> Self {
        assert!(!den.is_zero(), "rational with zero denominator");
        Rational { num, den }.normalize()
    }

    pub fn from_int(v: impl Into<BigInt>) -> Self {
        Rational { num: v.into(), den: BigInt::one() }
    }

    pub fn numerator(&self) -> &BigInt {
        &self.num
    }

    pub fn denominator(&self) -> &BigInt {
        &self.den
    }

    pub fn is_integer(&self) -> bool {
        self.den == BigInt::one()
    }

    pub fn recip(&self) -> Rational {
        assert!(!self.num.is_zero(), "reciprocal of zero");
        Rational::new(self.den.clone(), self.num.clone())
    }

    fn normalize(mut self) -> Self {
        if self.num.is_zero() {
            return Rational::zero();
        }
        if self.den.is_negative() {
            self.num = self.num.neg();
            self.den = self.den.neg();
        }
        let g = self.num.gcd(&self.den);
        if g != BigInt::one() {
            self.num = self.num.div_exact(&g);
            self.den = self.den.div_exact(&g);
        }
        self
    }
}

impl From<i64> for Rational {
    fn from(v: i64) -> Self {
        Rational::from_int(v)
    }
}

impl From<i32> for Rational {
    fn from(v: i32) -> Self {
        Rational::from_int(v as i64)
    }
}

impl std::fmt::Display for Rational {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_integer() {
            write!(f, "{}", self.num)
        } else {
            write!(f, "{}/{}", self.num, self.den)
        }
    }
}

impl Coeff for Rational {
    fn zero() -> Self {
        Rational::zero()
    }

    fn one() -> Self {
        Rational::one()
    }

    fn is_zero(&self) -> bool {
        self.num.is_zero()
    }

    fn add(&self, other: &Self) -> Self {
        // a/b + c/d = (ad + cb) / bd
        let num = &(&self.num * &other.den) + &(&other.num * &self.den);
        let den = &self.den * &other.den;
        Rational::new(num, den)
    }

    fn mul(&self, other: &Self) -> Self {
        Rational::new(&self.num * &other.num, &self.den * &other.den)
    }

    fn neg(&self) -> Self {
        Rational { num: self.num.neg(), den: self.den.clone() }
    }

    fn to_exact_f64(&self) -> Option<f64> {
        if !self.is_integer() {
            return None;
        }
        self.num.to_i128().and_then(|v| v.to_exact_f64())
    }

    fn from_exact_f64(v: f64) -> Option<Self> {
        i128::from_exact_f64(v).map(|i| Rational::from_int(BigInt::from(i)))
    }
}

impl FieldCoeff for Rational {
    fn div(&self, other: &Self) -> Self {
        assert!(!other.is_zero(), "rational division by zero");
        Rational::new(&self.num * &other.den, &self.den * &other.num)
    }
}

impl PartialOrd for Rational {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Rational {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // a/b vs c/d  (b, d > 0)  ⇔  ad vs cb
        (&self.num * &other.den).cmp(&(&other.num * &self.den))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::{runner, Gen};

    fn q(n: i64, d: i64) -> Rational {
        Rational::new(BigInt::from(n), BigInt::from(d))
    }

    #[test]
    fn normalization() {
        assert_eq!(q(2, 4), q(1, 2));
        assert_eq!(q(-2, -4), q(1, 2));
        assert_eq!(q(2, -4), q(-1, 2));
        assert_eq!(q(0, 5), Rational::zero());
        assert_eq!(q(6, 3).to_string(), "2");
        assert_eq!(q(1, 3).to_string(), "1/3");
        assert_eq!(q(-1, 3).to_string(), "-1/3");
    }

    #[test]
    #[should_panic(expected = "zero denominator")]
    fn zero_denominator_panics() {
        let _ = Rational::new(BigInt::one(), BigInt::zero());
    }

    #[test]
    fn field_operations() {
        assert_eq!(q(1, 2).add(&q(1, 3)), q(5, 6));
        assert_eq!(q(1, 2).mul(&q(2, 3)), q(1, 3));
        assert_eq!(FieldCoeff::div(&q(1, 2), &q(3, 4)), q(2, 3));
        assert_eq!(q(1, 3).add(&q(-1, 3)), Rational::zero());
        assert_eq!(q(2, 5).recip(), q(5, 2));
        assert_eq!(q(7, 3).neg().add(&q(7, 3)), Rational::zero());
    }

    #[test]
    fn ordering() {
        assert!(q(1, 3) < q(1, 2));
        assert!(q(-1, 2) < q(1, 3));
        assert!(q(2, 4) == q(1, 2));
    }

    #[test]
    fn exact_f64_bridge() {
        assert_eq!(q(6, 3).to_exact_f64(), Some(2.0));
        assert_eq!(q(1, 3).to_exact_f64(), None);
        assert_eq!(Rational::from_exact_f64(5.0), Some(q(5, 1)));
        assert_eq!(Rational::from_exact_f64(0.5), None);
    }

    #[test]
    fn prop_field_axioms() {
        let mut r = runner(300);
        r.run(|g: &mut Gen| {
            let a = q(g.i64_in(-50..=50), g.i64_in(1..=20));
            let b = q(g.i64_in(-50..=50), g.i64_in(1..=20));
            let c = q(g.i64_in(-50..=50), g.i64_in(1..=20));
            assert_eq!(a.add(&b), b.add(&a));
            assert_eq!(a.mul(&b), b.mul(&a));
            assert_eq!(a.add(&b).add(&c), a.add(&b.add(&c)));
            assert_eq!(a.mul(&b).mul(&c), a.mul(&b.mul(&c)));
            assert_eq!(a.mul(&b.add(&c)), a.mul(&b).add(&a.mul(&c)));
            assert_eq!(a.add(&a.neg()), Rational::zero());
            if !b.is_zero() {
                // (a/b)·b = a
                assert_eq!(FieldCoeff::div(&a, &b).mul(&b), a);
            }
        });
    }
}

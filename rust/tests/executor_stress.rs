//! Scheduler stress coverage for the work-stealing executor:
//! producer/stealer storms (under both deque implementations), the
//! par(1) deep-pipeline no-deadlock regression, and panic propagation
//! through stolen tasks.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use stream_future::exec::{DequeKind, Executor, ExecutorConfig};
use stream_future::prelude::*;
use stream_future::susp::Fut;

#[test]
fn producers_and_stealers_storm() {
    // 4 external producer threads × 500 tasks, each spawning 3 children
    // from inside the pool (children land in worker deques, where only
    // theft balances them). One extra task floods its own deque and then
    // sleeps, so at par ≥ 2 a nonzero steal count is guaranteed, not
    // merely probable.
    let ex = Executor::new(4);
    let total = Arc::new(AtomicUsize::new(0));

    {
        let ex2 = ex.clone();
        let t = total.clone();
        ex.spawn(move || {
            for _ in 0..200 {
                let t2 = t.clone();
                ex2.spawn(move || {
                    t2.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Hold this worker: its 200 children can only run via theft.
            std::thread::sleep(Duration::from_millis(30));
        });
    }

    std::thread::scope(|s| {
        for _ in 0..4 {
            let ex = ex.clone();
            let total = total.clone();
            s.spawn(move || {
                for _ in 0..500 {
                    let ex2 = ex.clone();
                    let t2 = total.clone();
                    ex.spawn(move || {
                        t2.fetch_add(1, Ordering::SeqCst);
                        for _ in 0..3 {
                            let t3 = t2.clone();
                            ex2.spawn(move || {
                                t3.fetch_add(1, Ordering::SeqCst);
                            });
                        }
                    });
                }
            });
        }
    });
    ex.wait_idle();

    let stats = ex.stats();
    assert_eq!(total.load(Ordering::SeqCst), 200 + 4 * 500 * 4);
    assert!(stats.tasks_stolen > 0, "work stealing must actually steal: {stats:?}");
    assert_eq!(stats.tasks_panicked, 0);
    assert_eq!(stats.queue_depth, 0, "idle pool holds no queued jobs");
}

#[test]
fn producer_storm_survives_both_deque_kinds() {
    // The storm above runs under the process-default deque; this pins
    // each implementation explicitly so a regression in one is
    // attributable regardless of SFUT_DEQUE.
    for kind in DequeKind::ALL {
        let mut cfg = ExecutorConfig::with_parallelism(4);
        cfg.deque = kind;
        let ex = Executor::with_config(cfg);
        let total = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..3 {
                let ex = ex.clone();
                let total = total.clone();
                s.spawn(move || {
                    for _ in 0..300 {
                        let ex2 = ex.clone();
                        let t2 = total.clone();
                        ex.spawn(move || {
                            t2.fetch_add(1, Ordering::SeqCst);
                            for _ in 0..2 {
                                let t3 = t2.clone();
                                ex2.spawn(move || {
                                    t3.fetch_add(1, Ordering::SeqCst);
                                });
                            }
                        });
                    }
                });
            }
        });
        ex.wait_idle();
        assert_eq!(total.load(Ordering::SeqCst), 3 * 300 * 3, "kind={kind:?}");
        let stats = ex.stats();
        assert_eq!(stats.tasks_panicked, 0, "kind={kind:?}");
        assert_eq!(stats.queue_depth, 0, "kind={kind:?}");
        assert!(stats.tasks_stolen >= stats.jobs_migrated, "kind={kind:?}: {stats:?}");
    }
}

#[test]
fn par1_forces_10k_deep_stream_without_deadlock() {
    // The killer configuration: a single worker, a spine of 10k dependent
    // suspensions, and a driver forcing through it. Managed blocking plus
    // stealable deques must keep it live end to end.
    let ex = Executor::new(1);
    let eval = FutureEval::new(ex.clone());
    let s = Stream::range(eval, 0, 10_000);
    assert_eq!(s.force_all(), 10_000);
    // And again with a transformation stage on the same exhausted pool.
    let eval = FutureEval::new(ex);
    let mapped = Stream::range(eval, 0, 10_000).map_elems(|x| x + 1);
    assert_eq!(mapped.len(), 10_000);
}

#[test]
fn panic_propagates_through_stolen_task() {
    // Worker A spawns the panicking future locally, then sleeps holding
    // its worker; the only way the future completes while A sleeps is
    // that worker B stole it. The panic must still surface at the
    // forcing site, with its message intact.
    let ex = Executor::new(2);
    let ex2 = ex.clone();
    let outer: Fut<Fut<u32>> = Fut::spawn(&ex, move || {
        let inner: Fut<u32> = Fut::spawn(&ex2, || panic!("stolen boom"));
        std::thread::sleep(Duration::from_millis(50));
        inner
    });
    let inner = outer.force().clone();
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        inner.force();
    }));
    let payload = res.expect_err("forcing a poisoned future must panic");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .unwrap_or_else(|| "non-string payload".to_string());
    assert!(msg.contains("stolen boom"), "payload: {msg}");
    ex.wait_idle();
    assert!(ex.stats().tasks_stolen >= 1, "inner future should have been stolen");
}

#[test]
fn mapping_a_completed_spine_trampolines() {
    // Regression for the inline-completion fast path: mapping over an
    // already-finished 50k-cell Future stream must not recurse the
    // caller's stack into the ground (the inline depth guard trampolines
    // onto worker stacks every MAX_INLINE_DEPTH cells).
    let ex = Executor::new(2);
    let eval = FutureEval::new(ex.clone());
    let s = Stream::range(eval, 0, 50_000);
    assert_eq!(s.force_all(), 50_000);
    ex.wait_idle(); // the whole spine is complete before we map
    let mapped = s.map_elems(|x| x.wrapping_mul(3));
    assert_eq!(mapped.len(), 50_000);
    assert_eq!(mapped.get(49_999), Some(49_999u32.wrapping_mul(3)));
}

#[test]
fn steals_zero_on_single_worker() {
    // par(1) has nobody to steal from; the counter must stay exact.
    let ex = Executor::new(1);
    let n = Arc::new(AtomicUsize::new(0));
    for _ in 0..1_000 {
        let n2 = n.clone();
        ex.spawn(move || {
            n2.fetch_add(1, Ordering::SeqCst);
        });
    }
    ex.wait_idle();
    assert_eq!(n.load(Ordering::SeqCst), 1_000);
    assert_eq!(ex.stats().tasks_stolen, 0);
}

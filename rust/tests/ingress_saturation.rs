//! Saturation behaviour of the staged ingress, end-to-end: a bounded
//! admission queue under deliberately overwhelming TCP traffic, shed
//! lines over the wire, deterministic shedding at the protocol level,
//! and the counter accounting that CI uploads as an artifact
//! (`INGRESS_saturation.json`).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;

use stream_future::config::{AdmissionPolicy, Config};
use stream_future::coordinator::{serve, Pipeline, TcpServer};
use stream_future::testkit::wire::{parse_err_line, ErrLine};

fn saturating_config() -> Config {
    let mut cfg = Config::default();
    cfg.primes_n = 300;
    cfg.fateman_degree = 2;
    cfg.chunk_size = 16;
    cfg.use_kernel = false;
    cfg.shards = 1;
    cfg.shard_parallelism = 1;
    cfg.dispatchers = 1;
    cfg.queue_depth = 1;
    cfg.admission = AdmissionPolicy::Shed;
    cfg
}

fn session(addr: std::net::SocketAddr, script: &str) -> Vec<String> {
    let mut sock = TcpStream::connect(addr).unwrap();
    sock.write_all(script.as_bytes()).unwrap();
    sock.flush().unwrap();
    sock.shutdown(std::net::Shutdown::Write).unwrap();
    BufReader::new(sock).lines().map(|l| l.unwrap()).collect()
}

/// Flood a queue_depth=1, single-runner pipeline from 6 concurrent TCP
/// sessions. Shedding is load-dependent, so the invariant checked is
/// accounting, not a shed count: every response line is either a
/// verified ok or a *well-formed* `err admission=shed` line, and the
/// wire totals reconcile exactly with the ingress counters.
#[test]
fn tcp_saturation_sheds_are_well_formed_and_accounted() {
    let pipeline = Arc::new(Pipeline::new(saturating_config()).unwrap());
    let server = TcpServer::start(Arc::clone(&pipeline), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();

    let sessions = 6usize;
    let jobs_per_session = 4usize;
    let script = "run primes par(2)\n".repeat(jobs_per_session);
    let all_lines: Vec<String> = std::thread::scope(|s| {
        let handles: Vec<_> =
            (0..sessions).map(|_| s.spawn(|| session(addr, &script))).collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });

    let total = sessions * jobs_per_session;
    assert_eq!(all_lines.len(), total, "one response line per request: {all_lines:?}");
    let mut oks = 0u64;
    let mut sheds = 0u64;
    for line in &all_lines {
        if line.starts_with("ok ") {
            assert!(line.contains("workload=primes"), "{line}");
            assert!(line.contains("verified=true"), "{line}");
            assert!(line.contains("queue_wait="), "{line}");
            oks += 1;
        } else {
            // The only legal rejection under admission=shed.
            match parse_err_line(line) {
                Some(ErrLine::Admission { policy, workload, mode, queue_depth, .. }) => {
                    assert_eq!(policy, "shed", "{line}");
                    assert_eq!(workload, "primes", "{line}");
                    assert_eq!(mode, "par(2)", "{line}");
                    assert_eq!(queue_depth, Some(1), "{line}");
                }
                other => panic!("unexpected response line: {line} (parsed: {other:?})"),
            }
            sheds += 1;
        }
    }
    assert_eq!(oks + sheds, total as u64);
    assert!(oks >= 1, "at least one job must get through");

    // Wire totals must reconcile with the ingress counters exactly.
    let snap = pipeline.metrics().snapshot();
    assert_eq!(snap.counters["jobs.completed"], oks, "completed == ok lines");
    assert_eq!(snap.counters.get("ingress.shed").copied().unwrap_or(0), sheds);
    assert_eq!(snap.counters["ingress.submitted"], total as u64);
    assert_eq!(snap.counters["ingress.admitted"], oks);
    // Nothing left queued once every session drained.
    assert_eq!(snap.gauges["ingress.queue_depth"], 0);

    // Gauge dump for the CI artifact: queue depth, shed rate, migration
    // counters alongside the BENCH files.
    let shed_rate = sheds as f64 / total as f64;
    let json = format!(
        "{{\n  \"bench\": \"ingress_saturation\",\n  \"profile\": \"{}\",\n  \
         \"sessions\": {sessions},\n  \"jobs_per_session\": {jobs_per_session},\n  \
         \"queue_depth\": 1,\n  \"admission\": \"shed\",\n  \"submitted\": {total},\n  \
         \"completed\": {oks},\n  \"shed\": {sheds},\n  \"shed_rate\": {shed_rate:.4},\n  \
         \"final_queue_depth\": {},\n  \"migrated_in\": {},\n  \"migrated_out\": {}\n}}\n",
        if cfg!(debug_assertions) { "debug" } else { "release" },
        snap.gauges["ingress.queue_depth"],
        pipeline.shards().iter().map(|s| s.migrated_in()).sum::<u64>(),
        pipeline.shards().iter().map(|s| s.migrated_out()).sum::<u64>(),
    );
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("INGRESS_saturation.json");
    std::fs::write(&out, json).expect("writing saturation gauge dump");
}

/// Deterministic shedding at the protocol level: with capacity 1 and a
/// single runner occupied by a slow job, a rapid `submit` burst of
/// equally slow jobs can admit at most one follower (the slot freed when
/// the runner picked up the first job) — everything else sheds. The
/// admitted work still completes and verifies afterwards.
#[test]
fn serve_submit_burst_sheds_deterministically() {
    let mut cfg = saturating_config();
    // Slow jobs: a stream-mode Fateman product dwarfs the microseconds
    // the submit burst takes to process.
    cfg.fateman_degree = 6;
    let pipeline = Pipeline::new(cfg).unwrap();
    let script = "submit stream par(2)\n".repeat(7) + "wait 1\n";
    let mut out = Vec::new();
    let jobs = serve(&pipeline, script.as_bytes(), &mut out).unwrap();
    let out = String::from_utf8(out).unwrap();
    assert_eq!(jobs, 1, "exactly one wait delivered a result: {out}");

    let tickets = out.lines().filter(|l| l.starts_with("ticket id=")).count();
    let sheds = out
        .lines()
        .filter(|l| {
            matches!(parse_err_line(l), Some(ErrLine::Admission { ref policy, .. })
                if policy == "shed")
        })
        .count();
    assert_eq!(tickets + sheds, 7, "every submit answered: {out}");
    assert!(tickets <= 2, "capacity 1 + one occupied runner admits at most 2: {out}");
    assert!(sheds >= 5, "the burst must shed: {out}");
    // The first (admitted) job completed and verified despite the storm.
    let ok = out.lines().find(|l| l.starts_with("ok ")).expect("wait 1 result");
    assert!(ok.contains("workload=stream"), "{ok}");
    assert!(ok.contains("verified=true"), "{ok}");
}

/// `admission=timeout(ms)` sheds late instead of instantly, and a
/// timed-out submission releases its would-be slot: follow-up traffic
/// admits normally once the backlog drains. (The fine-grained slot
/// accounting is covered by the ingress unit tests; this exercises the
/// policy end-to-end through the serve protocol.)
#[test]
fn timeout_admission_sheds_late_then_recovers() {
    let mut cfg = saturating_config();
    cfg.fateman_degree = 7;
    cfg.admission = AdmissionPolicy::Timeout(25);
    let pipeline = Pipeline::new(cfg).unwrap();
    let script = "submit stream par(2)\n".repeat(7) + "wait 1\n";
    let mut out = Vec::new();
    serve(&pipeline, script.as_bytes(), &mut out).unwrap();
    let out = String::from_utf8(out).unwrap();
    let tickets = out.lines().filter(|l| l.starts_with("ticket id=")).count();
    let timed_out: Vec<ErrLine> = out
        .lines()
        .filter_map(parse_err_line)
        .filter(|e| matches!(e, ErrLine::Admission { policy, .. } if policy == "timeout"))
        .collect();
    let timeouts = timed_out.len();
    assert_eq!(tickets + timeouts, 7, "every submit answered: {out}");
    // Each timed-out submission waited its full window at a genuinely
    // full queue (the slow jobs dwarf the burst); the exact split
    // depends on when the runner frees slots, but the storm cannot all
    // be admitted.
    assert!(timeouts >= 3, "the burst must time out at the full queue: {out}");
    assert!(
        timed_out
            .iter()
            .all(|e| matches!(e, ErrLine::Admission { waited_ms: Some(25), .. })),
        "every timeout names the configured window: {out}"
    );
    let snap = pipeline.metrics().snapshot();
    assert_eq!(snap.counters["ingress.timed_out"], timeouts as u64);
    // Timed-out submissions left no residue: once the slow backlog
    // drains, admission recovers (retry while earlier jobs still hold
    // the slot — a timeout here is the policy working, not a leak).
    let req = stream_future::coordinator::JobRequest::parse("primes par(2)").unwrap();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(120);
    let res = loop {
        match pipeline.run(&req) {
            Ok(res) => break res,
            Err(e) => {
                assert!(
                    std::time::Instant::now() < deadline,
                    "admission never recovered: {e:#}"
                );
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        }
    };
    assert!(res.verified);
    assert_eq!(pipeline.metrics().snapshot().gauges["ingress.queue_depth"], 0);
}

//! End-to-end coverage of the framed binary wire protocol on the
//! reactor pool: handshake and job lifecycle over real sockets, the
//! malformed-frame conformance corpus (every hostile input answers at
//! most one `err` frame and closes — never a panic, never a stuck
//! session) run under **every readiness backend the platform has**,
//! slow-loris and pipelined-batch framing, shed-based backpressure
//! against a non-draining reader, and the framed-vs-text saturation
//! trajectory that CI gates (`BENCH_ingress.json`).
//!
//! The reactors need a unix readiness syscall, so the whole suite is
//! unix-only. Pool-specific invariants (pinning, fanout, pool
//! shutdown) live in `reactor_pool.rs`.
#![cfg(unix)]

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use stream_future::bench_harness::{ingress_bench, BenchOptions, GateOutcome};
use stream_future::config::{AdmissionPolicy, Config, PollerKind, WireProtocol};
use stream_future::coordinator::frame::{self, Frame, FrameKind, MAX_FRAME_LEN};
use stream_future::coordinator::{Pipeline, TcpServer};
use stream_future::testkit::wire::{
    parse_err_line, read_to_eof, ErrLine, FramedClient, SubmitReply, STATE_READY,
};

fn smoke_config() -> Config {
    let mut cfg = Config::default();
    cfg.primes_n = 300;
    cfg.fateman_degree = 2;
    cfg.chunk_size = 16;
    cfg.use_kernel = false;
    cfg.shards = 1;
    cfg.shard_parallelism = 1;
    cfg.dispatchers = 1;
    cfg
}

/// Every readiness backend this platform can run: the conformance
/// corpus must hold under each, not just whichever `auto` picks.
fn test_pollers() -> Vec<PollerKind> {
    if cfg!(target_os = "linux") {
        vec![PollerKind::Poll, PollerKind::Epoll]
    } else {
        vec![PollerKind::Poll]
    }
}

fn framed_server(cfg: Config) -> (Arc<Pipeline>, TcpServer) {
    let pipeline = Arc::new(Pipeline::new(cfg).unwrap());
    let server =
        TcpServer::start_wire(Arc::clone(&pipeline), "127.0.0.1:0", WireProtocol::Framed).unwrap();
    (pipeline, server)
}

fn counter(pipeline: &Pipeline, name: &str) -> u64 {
    pipeline.metrics().snapshot().counters.get(name).copied().unwrap_or(0)
}

/// Happy path over real sockets: handshake, submit → ticket, wait →
/// verified result, poll → terminal state, workloads listing, and a
/// well-formed err frame for an unknown ticket.
#[test]
fn framed_session_submits_waits_and_polls() {
    let (pipeline, server) = framed_server(smoke_config());
    let mut client = FramedClient::connect(server.local_addr()).unwrap();

    let id = match client.submit("primes par(2)").unwrap() {
        SubmitReply::Ticket { id, .. } => id,
        SubmitReply::Err(e) => panic!("submit rejected: {e}"),
    };
    assert_eq!(id, 1, "first ticket of the session");
    let line = client.wait(id).unwrap();
    assert!(line.starts_with("ok "), "{line}");
    assert!(line.contains("workload=primes"), "{line}");
    assert!(line.contains("verified=true"), "{line}");
    assert_eq!(client.poll(id).unwrap(), STATE_READY);

    let listing = client.workloads().unwrap();
    assert!(listing.contains("primes"), "{listing}");

    // A ticket this session never issued answers one tagged err frame
    // on the documented taxonomy, and the session stays usable.
    client.send_wait(99).unwrap();
    let f = client.recv_expect().unwrap();
    assert_eq!(f.kind, FrameKind::Err);
    let err = FramedClient::line_of(&f).unwrap();
    assert!(
        matches!(parse_err_line(&err), Some(ErrLine::Other { .. })),
        "unknown-ticket reply must parse as a tagged err line: {err}"
    );
    let line = client.wait(id).unwrap();
    assert!(line.starts_with("ok "), "session still live after err: {line}");

    let frames_in = counter(&pipeline, "wire.frames_in");
    assert!(frames_in >= 6, "submit+wait+poll+workloads+2 waits, got {frames_in}");
}

/// The malformed-input corpus: every entry must produce at most one
/// well-formed `Err` frame followed by a clean close — and the server
/// must keep serving new sessions afterwards. The corpus is a protocol
/// contract, not a backend detail, so it runs under every readiness
/// backend the platform supports.
#[test]
fn conformance_corpus_answers_one_err_frame_then_closes() {
    for poller in test_pollers() {
        let mut cfg = smoke_config();
        cfg.poller = poller;
        conformance_corpus_one_backend(cfg);
    }
}

fn conformance_corpus_one_backend(cfg: Config) {
    let (pipeline, server) = framed_server(cfg);
    let addr = server.local_addr();

    // Garbage magic: err frame naming the magic, then EOF. No Hello.
    let mut sock = TcpStream::connect(addr).unwrap();
    sock.write_all(b"JUNK\x01").unwrap();
    let f = frame::read_frame(&mut sock).unwrap().expect("err frame for bad magic");
    assert_eq!(f.kind, FrameKind::Err);
    let line = FramedClient::line_of(&f).unwrap();
    assert!(line.contains("bad connection magic"), "{line}");
    assert_eq!(frame::read_frame(&mut sock).unwrap(), None, "closed after err");

    // Right magic, wrong version: the version err, then EOF.
    let mut sock = TcpStream::connect(addr).unwrap();
    sock.write_all(b"SFUT\x09").unwrap();
    let f = frame::read_frame(&mut sock).unwrap().expect("err frame for bad version");
    let line = FramedClient::line_of(&f).unwrap();
    assert!(line.contains("unsupported protocol version 9"), "{line}");
    assert_eq!(frame::read_frame(&mut sock).unwrap(), None);

    // Oversized declared length: rejected from the header alone,
    // before any payload is sent (or allocated server-side).
    let mut client = FramedClient::connect(addr).unwrap();
    let mut evil = ((MAX_FRAME_LEN as u32) + 1).to_le_bytes().to_vec();
    evil.push(FrameKind::Submit.as_u8());
    client.send_raw(&evil).unwrap();
    let f = client.recv_expect().unwrap();
    assert_eq!(f.kind, FrameKind::Err);
    let line = FramedClient::line_of(&f).unwrap();
    assert!(line.contains("exceeds cap"), "{line}");
    assert_eq!(client.recv().unwrap(), None, "closed after oversized header");

    // Unknown kind byte: one err naming the kind, then EOF.
    let mut client = FramedClient::connect(addr).unwrap();
    let mut evil = 0u32.to_le_bytes().to_vec();
    evil.push(9);
    client.send_raw(&evil).unwrap();
    let f = client.recv_expect().unwrap();
    assert_eq!(f.kind, FrameKind::Err);
    let line = FramedClient::line_of(&f).unwrap();
    assert!(line.contains("unknown frame kind 9"), "{line}");
    assert_eq!(client.recv().unwrap(), None);

    // A client-side frame kind from the *server* table is a protocol
    // violation too: err, then close.
    let mut client = FramedClient::connect(addr).unwrap();
    client.send(&Frame::new(FrameKind::Hello, vec![1])).unwrap();
    let f = client.recv_expect().unwrap();
    assert_eq!(f.kind, FrameKind::Err);
    let line = FramedClient::line_of(&f).unwrap();
    assert!(line.contains("unexpected client frame kind 16"), "{line}");
    assert_eq!(client.recv().unwrap(), None);

    let disconnects_before = counter(&pipeline, "wire.midframe_disconnects");

    // Truncated header then disconnect: nothing to answer — the bytes
    // completing the frame can never arrive. Clean close, counted.
    let mut client = FramedClient::connect(addr).unwrap();
    client.send_raw(&[0x02, 0x00]).unwrap();
    client.shutdown_write().unwrap();
    assert_eq!(client.recv().unwrap(), None, "mid-header disconnect closes quietly");

    // Valid header, payload cut short, disconnect: same quiet close.
    let mut client = FramedClient::connect(addr).unwrap();
    let mut partial = 10u32.to_le_bytes().to_vec();
    partial.push(FrameKind::Submit.as_u8());
    partial.extend_from_slice(b"pri");
    client.send_raw(&partial).unwrap();
    client.shutdown_write().unwrap();
    assert_eq!(client.recv().unwrap(), None, "mid-payload disconnect closes quietly");

    // Truncated *preamble* then disconnect is the handshake analogue.
    let mut sock = TcpStream::connect(addr).unwrap();
    sock.write_all(b"SF").unwrap();
    sock.shutdown(std::net::Shutdown::Write).unwrap();
    assert!(read_to_eof(&mut sock).unwrap().is_empty(), "no frames for a dead handshake");

    // The disconnect counter saw all three mid-frame cases, and the
    // server survived the whole corpus: a fresh session still works.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while counter(&pipeline, "wire.midframe_disconnects") < disconnects_before + 3 {
        assert!(std::time::Instant::now() < deadline, "mid-frame disconnects not counted");
        std::thread::sleep(Duration::from_millis(5));
    }
    let mut client = FramedClient::connect(addr).unwrap();
    let SubmitReply::Ticket { id, .. } = client.submit("primes par(2)").unwrap() else {
        panic!("post-corpus submit rejected");
    };
    let line = client.wait(id).unwrap();
    assert!(line.starts_with("ok "), "server dead after corpus: {line}");
}

/// A slow-loris client dribbles a valid submit frame one byte at a
/// time; the incremental decoder assembles it and the job completes.
#[test]
fn slow_loris_single_bytes_still_frame_correctly() {
    let (_pipeline, server) = framed_server(smoke_config());
    let mut client = FramedClient::connect(server.local_addr()).unwrap();
    let submit = Frame::new(FrameKind::Submit, b"primes par(2)".to_vec()).encode();
    for byte in &submit {
        client.send_raw(std::slice::from_ref(byte)).unwrap();
        std::thread::sleep(Duration::from_millis(2));
    }
    let f = client.recv_expect().unwrap();
    let SubmitReply::Ticket { id, .. } = FramedClient::submit_reply(&f).unwrap() else {
        panic!("loris submit rejected: {f:?}");
    };
    let line = client.wait(id).unwrap();
    assert!(line.starts_with("ok "), "{line}");
}

/// 100 pipelined submits in one write: the server decodes the whole
/// batch, answers tickets 1..=100 in submit order, and every job
/// resolves through pipelined waits.
#[test]
fn pipelined_batch_of_100_submits_in_one_write() {
    let (pipeline, server) = framed_server(smoke_config());
    let mut client = FramedClient::connect(server.local_addr()).unwrap();

    let jobs = 100u64;
    let mut batch = Vec::new();
    for _ in 0..jobs {
        Frame::new(FrameKind::Submit, b"primes par(2)".to_vec()).encode_into(&mut batch);
    }
    client.send_raw(&batch).unwrap();
    for expect in 1..=jobs {
        let f = client.recv_expect().unwrap();
        let SubmitReply::Ticket { id, .. } = FramedClient::submit_reply(&f).unwrap() else {
            panic!("batch submit {expect} rejected: {f:?}");
        };
        assert_eq!(id, expect, "tickets answer in submit order");
    }

    // Pipeline the waits too; results carry ids, so order is free.
    let mut waits = Vec::new();
    for id in 1..=jobs {
        Frame::new(FrameKind::Wait, id.to_le_bytes().to_vec()).encode_into(&mut waits);
    }
    client.send_raw(&waits).unwrap();
    let mut resolved = std::collections::BTreeSet::new();
    for _ in 0..jobs {
        let f = client.recv_expect().unwrap();
        assert_eq!(f.kind, FrameKind::Result, "all batch jobs succeed: {f:?}");
        let (id, _) = frame::take_ticket_id(&f.payload).unwrap();
        let line = FramedClient::line_of(&f).unwrap();
        assert!(line.starts_with("ok "), "{line}");
        assert!(resolved.insert(id), "duplicate result for ticket {id}");
    }
    assert_eq!(resolved.len(), jobs as usize);
    assert_eq!(counter(&pipeline, "jobs.completed"), jobs);
}

/// A non-draining reader cannot force unbounded buffering: with a
/// bounded queue under `shed`, a flood of pipelined submits is answered
/// by admission control (ticket or well-formed shed line per submit),
/// and the wire totals reconcile exactly with the ingress counters.
#[test]
fn backpressure_floods_shed_instead_of_buffering() {
    let mut cfg = smoke_config();
    cfg.queue_depth = 1;
    cfg.admission = AdmissionPolicy::Shed;
    let (pipeline, server) = framed_server(cfg);
    let mut client = FramedClient::connect(server.local_addr()).unwrap();

    let flood = 300usize;
    let mut batch = Vec::new();
    for _ in 0..flood {
        Frame::new(FrameKind::Submit, b"primes par(2)".to_vec()).encode_into(&mut batch);
    }
    // One write, no reads: the server must answer everything without
    // queueing more than `queue_depth` jobs.
    client.send_raw(&batch).unwrap();
    client.shutdown_write().unwrap();

    let mut tickets = 0u64;
    let mut sheds = 0u64;
    for f in client.drain().unwrap() {
        match FramedClient::submit_reply(&f).unwrap() {
            SubmitReply::Ticket { .. } => tickets += 1,
            SubmitReply::Err(line) => {
                match parse_err_line(&line) {
                    Some(ErrLine::Admission { policy, workload, queue_depth, .. }) => {
                        assert_eq!(policy, "shed", "{line}");
                        assert_eq!(workload, "primes", "{line}");
                        assert_eq!(queue_depth, Some(1), "{line}");
                    }
                    other => panic!("unexpected flood reply: {line} (parsed: {other:?})"),
                }
                sheds += 1;
            }
        }
    }
    assert_eq!(tickets + sheds, flood as u64, "every submit answered");
    assert!(sheds > 0, "a queue_depth=1 flood must shed");
    assert!(tickets >= 1, "at least one job must get through");
    assert_eq!(counter(&pipeline, "ingress.submitted"), flood as u64);
    assert_eq!(counter(&pipeline, "ingress.shed"), sheds);
    assert_eq!(counter(&pipeline, "ingress.admitted"), tickets);
}

/// The CI-gated A/B trajectory: one harness invocation sweeps framed
/// cells for every platform poller crossed with the reactor ladder,
/// plus text cells, the result self-gates cleanly, and the trajectory
/// file seeds only when absent (`cargo bench --bench ingress_wire`
/// owns the overwrite path).
#[test]
fn ingress_wire_trajectory_covers_both_wires_and_seeds() {
    let cfg = smoke_config();
    let params = ingress_bench::IngressBenchParams {
        connections: vec![1, 2],
        jobs_per_connection: 2,
        ..Default::default()
    };
    let opts = BenchOptions { warmup: 1, samples: 2, verbose: false };
    let b = ingress_bench::run(&cfg, &params, &opts).unwrap();

    // framed: pollers × reactor counts × connections; text: connections.
    let framed_cells =
        params.pollers.len() * params.reactor_counts.len() * params.connections.len();
    let expected = framed_cells + params.connections.len();
    assert_eq!(b.points.len(), expected, "points: {:?}", b.points);
    for wire in ["framed", "text"] {
        assert!(
            b.points.iter().any(|p| p.wire == wire),
            "one invocation must produce {wire} cells: {:?}",
            b.points
        );
    }
    // The framed sweep exercises every platform poller and at least two
    // reactor counts in the one invocation CI runs.
    for poller in &params.pollers {
        assert!(b.points.iter().any(|p| p.poller == poller.label()), "no {poller:?} cells");
    }
    let reactor_counts: std::collections::BTreeSet<usize> =
        b.points.iter().filter(|p| p.wire == "framed").map(|p| p.reactors).collect();
    assert!(
        reactor_counts.len() >= 2,
        "framed sweep covers only one reactor count: {reactor_counts:?}"
    );
    assert!(b.points.iter().all(|p| p.jobs_per_sec > 0.0));
    assert!(b.points.iter().all(|p| p.p95_ms >= p.p50_ms));
    // Default admission is block: nothing sheds during the sweep.
    assert!(b.points.iter().all(|p| p.shed_rate == 0.0));

    let json = ingress_bench::to_json(&b);
    assert!(json.contains("\"bench\": \"ingress_wire_saturation\""));
    let report =
        ingress_bench::gate(&json, &json, 0.25, 0.25, false).expect("self-gate must not error");
    match report.outcome {
        GateOutcome::Passed { cells } => assert_eq!(cells, expected),
        other => panic!("expected self-gate pass, got {other:?}"),
    }
    assert!(report.warnings.is_empty(), "{:?}", report.warnings);

    let _ = ingress_bench::write_json_if_absent(&b);
    assert!(ingress_bench::default_output_path().exists());
}

//! Model tests for the per-worker stealable deques — run against BOTH
//! implementations ([`DequeKind::ChaseLev`] and [`DequeKind::Locked`]),
//! so CI can pin a steal-path regression to one of them at a glance
//! (`ci.yml` runs this file as a named step under `SFUT_DEQUE=chase_lev`
//! and `SFUT_DEQUE=locked`; the kind-parameterized tests below cover
//! both regardless of the env default).
//!
//! The invariants checked:
//!
//! * **No job lost or duplicated** under one owner racing N concurrent
//!   thieves (per-job execution flags — every job runs exactly once —
//!   plus a checksum over executed job ids).
//! * **Index wraparound**: the Chase–Lev ring's wrapping `u64` indices
//!   survive crossing the `u64::MAX` → `0` boundary, single-threaded
//!   and under concurrency ([`ChaseLevDeque::with_start_index`]).
//! * **Grow under steal**: buffer growth (16 → thousands of slots)
//!   while thieves are mid-steal neither loses jobs nor frees a buffer
//!   a thief still reads (the pin/limbo retirement path).
//! * **Steal-half sizing**: a batch steal takes at most ⌈len/2⌉ jobs
//!   (capped at [`MAX_STEAL_BATCH`]), the victim keeps the newer half
//!   with its LIFO order undisturbed, and the thief's deque receives
//!   the rest.
//! * **Pool-level batch accounting**: `steals_batched`/`jobs_migrated`
//!   counters stay mutually consistent under both kinds.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use stream_future::exec::{
    ChaseLevDeque, DequeKind, Executor, ExecutorConfig, WorkerDeque, MAX_STEAL_BATCH,
};

/// One execution flag per job: `run_all` asserts each flag is exactly 1,
/// which catches losses AND duplications (a checksum alone could cancel
/// one of each).
fn flag_job(
    flags: &Arc<Vec<AtomicUsize>>,
    checksum: &Arc<AtomicUsize>,
    id: usize,
) -> Box<dyn FnOnce() + Send> {
    let flags = Arc::clone(flags);
    let checksum = Arc::clone(checksum);
    Box::new(move || {
        flags[id].fetch_add(1, Ordering::SeqCst);
        checksum.fetch_add(id, Ordering::SeqCst);
    })
}

fn assert_each_ran_once(flags: &[AtomicUsize], checksum: &AtomicUsize, label: &str) {
    let n = flags.len();
    for (id, f) in flags.iter().enumerate() {
        assert_eq!(f.load(Ordering::SeqCst), 1, "{label}: job {id} ran a wrong number of times");
    }
    assert_eq!(checksum.load(Ordering::SeqCst), n * (n - 1) / 2, "{label}: id checksum");
}

/// One owner pushing (and sometimes popping) N jobs against `thieves`
/// concurrent batch-stealing thieves, each landing batches in its own
/// deque and draining it. Every job must execute exactly once.
fn owner_vs_thieves(kind: DequeKind, victim: WorkerDeque, n: usize, thieves: usize) {
    let victim = Arc::new(victim);
    let flags = Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>());
    let checksum = Arc::new(AtomicUsize::new(0));
    let executed = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|s| {
        // Owner.
        {
            let victim = Arc::clone(&victim);
            let flags = Arc::clone(&flags);
            let checksum = Arc::clone(&checksum);
            let executed = Arc::clone(&executed);
            s.spawn(move || {
                for id in 0..n {
                    let executed = Arc::clone(&executed);
                    let job = flag_job(&flags, &checksum, id);
                    // SAFETY: this spawned thread is the deque's
                    // sole owner-end user while it runs.
                    unsafe {
                        victim.push(Box::new(move || {
                            job();
                            executed.fetch_add(1, Ordering::SeqCst);
                        }))
                    };
                    // Pop (LIFO) every few pushes: the owner-vs-thief
                    // race on the bottom end is the hard part of the
                    // protocol.
                    if id % 5 == 0 {
                        if let Some(job) = unsafe { victim.pop() } {
                            job();
                        }
                    }
                }
                // Drain whatever the thieves left behind.
                while let Some(job) = unsafe { victim.pop() } {
                    job();
                }
            });
        }
        // Thieves: batch-steal into a private deque, run the first job,
        // then drain the private deque (the thief is its owner).
        for _ in 0..thieves {
            let victim = Arc::clone(&victim);
            let executed = Arc::clone(&executed);
            s.spawn(move || {
                let own = WorkerDeque::with_kind(kind);
                while executed.load(Ordering::SeqCst) < n {
                    // SAFETY: `own` was created by and is private
                    // to this thief thread.
                    match unsafe { victim.steal_batch_and_pop(&own) } {
                        Some((job, _moved)) => {
                            job();
                            while let Some(j) = unsafe { own.pop() } {
                                j();
                            }
                        }
                        None => std::thread::yield_now(),
                    }
                }
            });
        }
    });
    assert_eq!(executed.load(Ordering::SeqCst), n, "kind={kind:?}");
    assert_each_ran_once(&flags, &checksum, kind.label());
}

#[test]
fn no_loss_or_duplication_under_concurrent_thieves() {
    const N: usize = 30_000;
    for kind in DequeKind::ALL {
        owner_vs_thieves(kind, WorkerDeque::with_kind(kind), N, 4);
    }
}

#[test]
fn chase_lev_wraparound_under_concurrency() {
    // Indices start 1000 below the u64 boundary, so the wrap happens
    // while the owner and thieves are racing.
    const N: usize = 20_000;
    let deque = WorkerDeque::from(ChaseLevDeque::with_start_index(u64::MAX - 1_000));
    owner_vs_thieves(DequeKind::ChaseLev, deque, N, 3);
}

#[test]
fn chase_lev_wraparound_single_threaded_semantics() {
    // Start so close to the boundary that every operation straddles it.
    let d = ChaseLevDeque::with_start_index(u64::MAX);
    let hits = Arc::new(AtomicUsize::new(0));
    for _ in 0..3 {
        let hits = Arc::clone(&hits);
        unsafe {
            d.push(Box::new(move || {
                hits.fetch_add(1, Ordering::SeqCst);
            }))
        };
    }
    assert_eq!(d.len(), 3);
    d.steal().expect("oldest job stealable across the boundary")();
    unsafe { d.pop() }.expect("newest job poppable across the boundary")();
    unsafe { d.pop() }.expect("last job")();
    assert_eq!(hits.load(Ordering::SeqCst), 3);
    assert!(d.is_empty());
    assert!(unsafe { d.pop() }.is_none());
    assert!(d.steal().is_none());
}

#[test]
fn grow_under_steal_loses_nothing() {
    // The ring starts at 16 slots; pushing thousands of jobs in a burst
    // (no owner pops) forces repeated grows while thieves are actively
    // stealing — the window in which a retired buffer must stay
    // readable until every pinned thief moves off it.
    const N: usize = 8_192;
    for start in [0u64, u64::MAX - 4_000] {
        let victim = Arc::new(WorkerDeque::from(ChaseLevDeque::with_start_index(start)));
        let flags = Arc::new((0..N).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>());
        let checksum = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(AtomicUsize::new(0));
        let pushed_all = Arc::new(AtomicBool::new(false));
        std::thread::scope(|s| {
            for _ in 0..3 {
                let victim = Arc::clone(&victim);
                let done = Arc::clone(&done);
                let pushed_all = Arc::clone(&pushed_all);
                s.spawn(move || loop {
                    match victim.steal() {
                        Some(job) => {
                            job();
                            done.fetch_add(1, Ordering::SeqCst);
                        }
                        None => {
                            if pushed_all.load(Ordering::SeqCst) && victim.is_empty() {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                });
            }
            {
                let victim = Arc::clone(&victim);
                let flags = Arc::clone(&flags);
                let checksum = Arc::clone(&checksum);
                let pushed_all = Arc::clone(&pushed_all);
                s.spawn(move || {
                    for id in 0..N {
                        // SAFETY: this thread is the sole owner-end user.
                        unsafe { victim.push(flag_job(&flags, &checksum, id)) };
                    }
                    pushed_all.store(true, Ordering::SeqCst);
                });
            }
        });
        // Owner thread is gone (scope join = happens-before), so the
        // main thread is now the owner; anything not stolen drains here.
        while let Some(job) = unsafe { victim.pop() } {
            job();
            done.fetch_add(1, Ordering::SeqCst);
        }
        assert_eq!(done.load(Ordering::SeqCst), N, "start={start}");
        assert_each_ran_once(&flags, &checksum, "grow_under_steal");
    }
}

#[test]
fn steal_half_takes_at_most_ceil_half() {
    for kind in DequeKind::ALL {
        for len in [1usize, 2, 3, 7, 10, 2 * MAX_STEAL_BATCH + 5] {
            let victim = WorkerDeque::with_kind(kind);
            let dest = WorkerDeque::with_kind(kind);
            let ran = Arc::new(AtomicUsize::new(0));
            for _ in 0..len {
                let ran = Arc::clone(&ran);
                unsafe {
                    victim.push(Box::new(move || {
                        ran.fetch_add(1, Ordering::SeqCst);
                    }))
                };
            }
            let (first, moved) =
                unsafe { victim.steal_batch_and_pop(&dest) }.expect("non-empty victim");
            let taken = moved + 1;
            assert!(taken <= len.div_ceil(2), "kind={kind:?} len={len} taken={taken}");
            assert!(taken <= MAX_STEAL_BATCH, "kind={kind:?} len={len} taken={taken}");
            // Uncontended, the thief gets exactly the allowed half.
            assert_eq!(taken, len.div_ceil(2).min(MAX_STEAL_BATCH), "kind={kind:?} len={len}");
            assert_eq!(victim.len(), len - taken);
            assert_eq!(dest.len(), moved);
            first();
            assert_eq!(ran.load(Ordering::SeqCst), 1);
        }
    }
}

#[test]
fn steal_half_victim_keeps_lifo_order() {
    for kind in DequeKind::ALL {
        let victim = WorkerDeque::with_kind(kind);
        let dest = WorkerDeque::with_kind(kind);
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));
        for tag in 0..9u32 {
            let order = Arc::clone(&order);
            unsafe { victim.push(Box::new(move || order.lock().unwrap().push(tag))) };
        }
        // ⌈9/2⌉ = 5 taken: first = oldest (0), moved = 1..=4.
        let (first, moved) = unsafe { victim.steal_batch_and_pop(&dest) }.expect("non-empty");
        assert_eq!(moved, 4, "kind={kind:?}");
        first();
        // Victim pops its survivors newest-first: 8, 7, 6, 5.
        while let Some(job) = unsafe { victim.pop() } {
            job();
        }
        // Dest pops its share newest-first: 4, 3, 2, 1.
        while let Some(job) = unsafe { dest.pop() } {
            job();
        }
        assert_eq!(
            *order.lock().unwrap(),
            vec![0, 8, 7, 6, 5, 4, 3, 2, 1],
            "kind={kind:?}"
        );
    }
}

#[test]
fn pool_batch_steal_counters_stay_consistent() {
    for kind in DequeKind::ALL {
        let mut cfg = ExecutorConfig::with_parallelism(4);
        cfg.deque = kind;
        let ex = Executor::with_config(cfg);
        let total = Arc::new(AtomicUsize::new(0));
        // One worker floods its own deque then stalls: the children can
        // only run via theft, and a 400-deep run guarantees thieves see
        // batchable depth.
        let ex2 = ex.clone();
        let t2 = Arc::clone(&total);
        ex.spawn(move || {
            for _ in 0..400 {
                let t3 = Arc::clone(&t2);
                ex2.spawn(move || {
                    t3.fetch_add(1, Ordering::SeqCst);
                });
            }
            std::thread::sleep(std::time::Duration::from_millis(40));
        });
        ex.wait_idle();
        assert_eq!(total.load(Ordering::SeqCst), 400, "kind={kind:?}");
        let st = ex.stats();
        assert!(st.tasks_stolen > 0, "kind={kind:?}: flooded deque must be stolen from");
        // Every migrated job is a stolen job, and a batched steal moved
        // at least one job.
        assert!(st.tasks_stolen >= st.jobs_migrated, "kind={kind:?}");
        assert!(st.jobs_migrated >= st.steals_batched, "kind={kind:?}");
        if st.steals_batched > 0 {
            assert!(st.jobs_migrated_per_steal() >= 1.0, "kind={kind:?}");
        }
    }
}

#[test]
fn default_kind_drives_worker_deques() {
    // `WorkerDeque::new()` (what the pool builds when a config does not
    // override) follows the process default — SFUT_DEQUE when set. This
    // is the hook CI's per-kind named steps rely on.
    assert_eq!(WorkerDeque::new().kind(), DequeKind::default_kind());
    assert_eq!(
        ExecutorConfig::with_parallelism(2).deque,
        DequeKind::default_kind()
    );
}

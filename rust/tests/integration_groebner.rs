//! Integration: the Gröbner application over the exact-rational
//! substrate, cross-checked between execution modes and against
//! ideal-membership facts.

use stream_future::exec::Executor;
use stream_future::poly::groebner::{buchberger_par, buchberger_seq, is_groebner};
use stream_future::poly::{parse_polynomial, Coeff, Polynomial};
use stream_future::rational::Rational;
use stream_future::testkit::prop::{runner, Gen};

fn p3(s: &str) -> Polynomial<Rational> {
    parse_polynomial(s, &["x", "y", "z"]).unwrap()
}

#[test]
fn cyclic3_known_basis_shape() {
    let gens = [p3("x + y + z"), p3("x*y + y*z + z*x"), p3("x*y*z - 1")];
    let basis = buchberger_seq(&gens);
    assert!(is_groebner(&basis));
    // Reduced grlex basis of cyclic-3 has 3 elements with leading
    // monomials x, y^2 (after x-elimination), z^3.
    assert_eq!(basis.len(), 3);
    let leads: Vec<String> =
        basis.iter().map(|b| b.leading().unwrap().0.to_string()).collect();
    assert!(leads.contains(&"x".to_string()), "{leads:?}");
    assert!(leads.contains(&"z^3".to_string()), "{leads:?}");
}

#[test]
fn parallel_equals_sequential_on_random_ideals() {
    // Buchberger's running time is wildly input-sensitive; keep the
    // random generators tiny (2 vars, degree <= 2, 2 gens max) so the
    // worst sampled ideal still terminates in milliseconds. Pathological
    // cases belong in the (curated) unit tests, not a property sweep.
    let ex = Executor::new(3);
    let mut r = runner(8);
    r.run(move |g: &mut Gen| {
        let gens: Vec<Polynomial<Rational>> = (0..g.usize_in(1..3))
            .map(|_| random_poly(g))
            .filter(|p| !p.is_zero())
            .collect();
        if gens.is_empty() {
            return;
        }
        let seq = buchberger_seq(&gens);
        let par = buchberger_par(&ex, &gens);
        assert_eq!(seq, par, "gens={gens:?}");
        assert!(is_groebner(&seq));
    });
}

#[test]
fn ideal_membership_is_mode_independent() {
    let gens = [p3("x^2 - y*z"), p3("y^2 - x*z")];
    let ex = Executor::new(2);
    let basis = buchberger_par(&ex, &gens);
    // Products of generators are members.
    let member = gens[0].mul(&gens[1]);
    assert!(member.normal_form(&basis).is_zero());
    // S-polynomial of the generators is a member too.
    let s = stream_future::poly::groebner::s_polynomial(&gens[0], &gens[1]);
    assert!(s.normal_form(&basis).is_zero());
}

#[test]
fn rational_coefficients_stay_exact_through_buchberger() {
    // A system whose reductions produce non-dyadic fractions (thirds),
    // the exact case f64 gets wrong.
    let gens = [
        p3("3*x^2 + y - 1"),
        p3("x + 3*y^2 - 1"),
    ];
    let basis = buchberger_seq(&gens);
    assert!(is_groebner(&basis));
    // Every coefficient is a normalized exact rational (denominator > 0,
    // reduced); spot-check by re-parsing the display form round-trips
    // denominators like 1/3.
    let has_fraction = basis.iter().any(|b| {
        b.terms().iter().any(|(_, c)| !c.is_zero() && c.to_exact_f64().is_none())
    });
    assert!(has_fraction, "expected non-integer rationals in {basis:?}");
}

fn random_poly(g: &mut Gen) -> Polynomial<Rational> {
    let terms = g.vec(1..4, |g| {
        // 2 effective variables, total degree <= 2 per monomial.
        let e0 = g.u32_in(0..3) as u16;
        let e1 = g.u32_in(0..(3 - e0.min(2) as u32)) as u16;
        (
            stream_future::poly::Monomial::from_exps(vec![e0, e1, 0]),
            Rational::from(g.i64_in(-4..=4)),
        )
    });
    Polynomial::from_terms(3, terms)
}

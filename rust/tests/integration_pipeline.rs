//! Integration: the full three-layer pipeline — coordinator routing,
//! stream/future algorithms, and the PJRT kernel path — against the
//! independent oracles.

use std::path::Path;
use std::sync::Arc;

use stream_future::config::{Config, Mode};
use stream_future::coordinator::{serve, JobRequest, Pipeline};
use stream_future::poly::{chunked_times, RustMultiplier};
use stream_future::prelude::*;
use stream_future::runtime::{KernelMultiplier, KernelSiever, XlaEngine};
use stream_future::sieve;
use stream_future::workload::fateman_pair;

fn test_config() -> Config {
    let mut cfg = Config::default();
    cfg.primes_n = 1_000;
    cfg.fateman_degree = 4;
    cfg.chunk_size = 32;
    cfg.artifacts_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg
}

fn have_artifacts() -> bool {
    test_config().artifacts_dir.join("manifest.toml").exists()
}

#[test]
fn pipeline_with_kernel_runs_chunked_workloads() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let pipeline = Pipeline::new(test_config()).unwrap();
    assert!(pipeline.engine().is_some(), "engine must start when artifacts exist");
    for mode in [Mode::Seq, Mode::Par(2)] {
        let res = pipeline.run(&JobRequest::named("chunked", mode)).unwrap();
        assert!(res.verified, "chunked {mode:?} failed verification");
        assert_eq!(res.backend, "pjrt-kernel");
    }
    // The big variant is f64-inexact → generic path, still through the
    // same chunked code, still verified.
    let res = pipeline.run(&JobRequest::named("chunked_big", Mode::Par(2))).unwrap();
    assert!(res.verified);
    let stats = pipeline.engine().unwrap().stats();
    assert!(stats.poly_calls > 0, "kernel must actually be invoked");
}

#[test]
fn kernel_and_rust_multipliers_agree_on_fateman() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Arc::new(XlaEngine::start(&test_config().artifacts_dir).unwrap());
    let (p, q) = fateman_pair(4, 5);
    let want = p.mul(&q);
    for chunk in [7, 32, 128] {
        let via_kernel = chunked_times(
            &LazyEval,
            &p,
            &q,
            chunk,
            Arc::new(KernelMultiplier::new(Arc::clone(&engine))),
        );
        assert_eq!(via_kernel, want, "kernel path, chunk={chunk}");
        let via_rust = chunked_times(&LazyEval, &p, &q, chunk, Arc::new(RustMultiplier));
        assert_eq!(via_rust, want, "rust path, chunk={chunk}");
    }
}

#[test]
fn kernel_siever_full_sieve_matches_oracle() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Arc::new(XlaEngine::start(&test_config().artifacts_dir).unwrap());
    let siever = Arc::new(KernelSiever::new(engine));
    let oracle = sieve::eratosthenes(20_000);
    let got = sieve::chunked_primes_with_runtime(LazyEval, 20_000, 512, siever.clone());
    assert_eq!(got, oracle);
    // Parallel: blocks fan out as future tasks, all hitting the engine.
    let ex = Executor::new(3);
    let got = sieve::chunked_primes_with_runtime(FutureEval::new(ex), 20_000, 512, siever);
    assert_eq!(got, oracle);
}

#[test]
fn serve_session_over_kernel_pipeline() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let pipeline = Pipeline::new(test_config()).unwrap();
    let script = "run chunked par(2)\nrun primes seq\nmetrics\nquit\n";
    let mut out = Vec::new();
    let jobs = serve(&pipeline, script.as_bytes(), &mut out).unwrap();
    let out = String::from_utf8(out).unwrap();
    assert_eq!(jobs, 2);
    assert!(out.contains("ok workload=chunked mode=par(2)"));
    assert!(out.contains("backend=pjrt-kernel"));
    assert!(out.contains("jobs.completed"));
}

#[test]
fn pipeline_without_kernel_falls_back() {
    let mut cfg = test_config();
    cfg.use_kernel = false;
    let pipeline = Pipeline::new(cfg).unwrap();
    assert!(pipeline.engine().is_none());
    let res = pipeline.run(&JobRequest::named("chunked", Mode::Seq)).unwrap();
    assert!(res.verified);
    assert_eq!(res.backend, "rust-scalar");
}

#[test]
fn missing_artifacts_dir_falls_back_silently() {
    let mut cfg = test_config();
    cfg.artifacts_dir = "/definitely/not/here".into();
    let pipeline = Pipeline::new(cfg).unwrap();
    assert!(pipeline.engine().is_none());
}

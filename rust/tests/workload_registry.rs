//! Registry conformance suite: every registered workload must uphold
//! the plugin contract the open API promises.
//!
//! For every workload in the builtin registry:
//! * `seq` self-verifies against the plugin's independent oracle;
//! * `par(2)` and `strict` produce the *same* [`ResultDetail`] as
//!   `seq` — the paper's claim (substituting the monad never changes
//!   results), enforced per plugin;
//! * unknown names and malformed params answer well-formed `err` lines
//!   over the serve protocol, without occupying queue capacity.
//!
//! Also proves the open world end-to-end: a custom plugin defined in
//! *this test file* is registered via [`Pipeline::with_registry`] and
//! served (run + verify + wire protocol) with zero coordinator edits.
//!
//! Runs as a named CI step (`cargo test --test workload_registry`).

use std::collections::BTreeMap;
use std::sync::Arc;

use stream_future::config::{Config, Mode};
use stream_future::coordinator::{serve, JobRequest, Pipeline, ResultDetail};
use stream_future::prelude::*;
use stream_future::testkit::wire::parse_err_line;
use stream_future::workload::{ParamKind, ParamSpec, WorkloadError};

fn small_config() -> Config {
    let mut cfg = Config::default();
    cfg.primes_n = 400;
    cfg.fateman_degree = 2;
    cfg.chunk_size = 16;
    cfg.scale = 0.5; // shrinks fib/msort defaults; primes/fateman set above
    cfg.use_kernel = false;
    cfg
}

#[test]
fn every_registered_workload_self_verifies_and_agrees_across_modes() {
    let pipeline = Pipeline::new(small_config()).unwrap();
    let names = pipeline.registry().names();
    assert!(names.len() >= 11, "registry unexpectedly small: {names:?}");
    let mut seq_details: BTreeMap<String, ResultDetail> = BTreeMap::new();
    for w in &names {
        let seq = pipeline.run(&JobRequest::named(w, Mode::Seq)).unwrap();
        assert!(seq.verified, "{w} seq failed self-verification");
        seq_details.insert(w.clone(), seq.detail);
    }
    for w in &names {
        let par = pipeline.run(&JobRequest::named(w, Mode::Par(2))).unwrap();
        assert!(par.verified, "{w} par(2) failed verification");
        assert_eq!(
            par.detail, seq_details[w],
            "{w}: par(2) detail must equal seq detail"
        );
        let strict = pipeline.run(&JobRequest::named(w, Mode::Strict)).unwrap();
        assert!(strict.verified, "{w} strict failed verification");
        assert_eq!(
            strict.detail, seq_details[w],
            "{w}: strict detail must equal seq detail"
        );
    }
}

#[test]
fn unknown_names_and_malformed_params_answer_well_formed_err_lines() {
    let pipeline = Pipeline::new(small_config()).unwrap();
    let script = "run warp seq\n\
                  run primes(frobnicate=1) seq\n\
                  run primes(n=banana) par(2)\n\
                  run fib(n=64 seq\n\
                  submit warp par(2)\n\
                  run primes(n=100) seq\n\
                  quit\n";
    let mut out = Vec::new();
    let jobs = serve(&pipeline, script.as_bytes(), &mut out).unwrap();
    let out = String::from_utf8(out).unwrap();
    assert_eq!(jobs, 1, "{out}");
    let errs: Vec<&str> = out.lines().filter(|l| parse_err_line(l).is_some()).collect();
    assert_eq!(errs.len(), 5, "{out}");
    assert!(out.contains("unknown workload: warp"), "{out}");
    assert!(out.contains("unknown parameter: frobnicate"), "{out}");
    assert!(out.contains("bad value for param n"), "{out}");
    assert!(out.contains("unbalanced"), "{out}");
    // The one well-formed request still ran, params honored.
    assert!(out.contains("ok workload=primes(n=100) mode=seq"), "{out}");
    assert!(out.contains("primes=25"), "{out}");
    // Rejections never occupied queue capacity.
    assert_eq!(pipeline.ingress().pending(), 0);
    let snap = pipeline.metrics().snapshot();
    assert_eq!(snap.counters["ingress.rejected"], 4); // parse error never reached submit
    assert_eq!(snap.counters["ingress.admitted"], 1);
}

#[test]
fn params_override_defaults_and_feed_verification() {
    let pipeline = Pipeline::new(small_config()).unwrap();
    // Same workload, different params → different (still verified)
    // results; the oracle re-aims with the params.
    let small = pipeline
        .run(&JobRequest::parse("fib(n=10) par(2)").unwrap())
        .unwrap();
    let large = pipeline
        .run(&JobRequest::parse("fib(n=64) par(2)").unwrap())
        .unwrap();
    assert!(small.verified && large.verified);
    assert_ne!(small.detail, large.detail);
    assert_eq!(small.detail, ResultDetail::Scalar { value: "88".into() });
    // The big-coefficient knob is a param now: stream(big_factor=...)
    // equals the stream_big registration's result.
    let factor = pipeline.config().big_factor;
    let via_param = pipeline
        .run(&JobRequest::parse(&format!("stream(big_factor={factor}) seq")).unwrap())
        .unwrap();
    let via_registration = pipeline.run(&JobRequest::named("stream_big", Mode::Seq)).unwrap();
    assert!(via_param.verified && via_registration.verified);
    assert_eq!(via_param.detail, via_registration.detail);
}

/// A workload that exists only in this test file: sums `Stream::range`
/// via the generic stream machinery. If this runs, verifies, and serves
/// over the protocol, the coordinator is provably workload-agnostic.
struct RangeSumWorkload;

struct RangeSumBody {
    hi: u32,
}

impl stream_future::workload::EvalBody for RangeSumBody {
    type Out = u64;

    fn run<E: Eval>(self, eval: E) -> u64 {
        Stream::range(eval, 0, self.hi).fold(0u64, |acc, x| acc + u64::from(*x))
    }
}

impl StreamWorkload for RangeSumWorkload {
    fn name(&self) -> &str {
        "range_sum"
    }

    fn describe(&self) -> &str {
        "sum of 0..hi via the monadic stream (conformance-suite custom plugin)"
    }

    fn params(&self) -> Vec<ParamSpec> {
        vec![ParamSpec::new("hi", ParamKind::U32, "1000", "exclusive upper bound")]
    }

    fn run(
        &self,
        ctx: &WorkloadCtx<'_>,
        mode: Mode,
        params: &Params,
    ) -> Result<ResultDetail, WorkloadError> {
        let hi = params.get_u32("hi", 1000)?;
        let sum = ctx.run_mode(mode, RangeSumBody { hi });
        Ok(ResultDetail::Scalar { value: sum.to_string() })
    }

    fn verify(&self, _ctx: &WorkloadCtx<'_>, params: &Params, detail: &ResultDetail) -> bool {
        let Ok(hi) = params.get_u32("hi", 1000) else {
            return false;
        };
        // Closed form: sum 0..hi = hi(hi-1)/2.
        let want = u64::from(hi) * u64::from(hi.saturating_sub(1)) / 2;
        matches!(detail, ResultDetail::Scalar { value } if *value == want.to_string())
    }
}

#[test]
fn custom_plugin_serves_through_an_untouched_coordinator() {
    let mut registry = WorkloadRegistry::builtin();
    registry.register(Arc::new(RangeSumWorkload)).unwrap();
    let pipeline = Pipeline::with_registry(small_config(), registry).unwrap();

    // Direct API path, all three mode families.
    let seq = pipeline.run(&JobRequest::named("range_sum", Mode::Seq)).unwrap();
    assert!(seq.verified);
    assert_eq!(seq.detail, ResultDetail::Scalar { value: "499500".into() });
    let par = pipeline.run(&JobRequest::parse("range_sum(hi=100) par(2)").unwrap()).unwrap();
    assert!(par.verified);
    assert_eq!(par.detail, ResultDetail::Scalar { value: "4950".into() });

    // Wire path: listed by the workloads verb, runnable with params,
    // schema-checked.
    let script = "workloads\nrun range_sum(hi=10) par(2)\nrun range_sum(lo=1) seq\nquit\n";
    let mut out = Vec::new();
    let jobs = serve(&pipeline, script.as_bytes(), &mut out).unwrap();
    let out = String::from_utf8(out).unwrap();
    assert_eq!(jobs, 1, "{out}");
    assert!(out.contains("workload name=range_sum params=[hi:u32=1000]"), "{out}");
    assert!(out.contains("ok workload=range_sum(hi=10) mode=par(2)"), "{out}");
    assert!(out.contains("value=45"), "{out}");
    assert!(out.contains("unknown parameter: lo"), "{out}");

    // Affinity routes the new name deterministically like any other.
    assert!(pipeline.shards().home_index("range_sum") < pipeline.shards().len());
}

#[test]
fn duplicate_registration_is_refused() {
    let mut registry = WorkloadRegistry::builtin();
    registry.register(Arc::new(RangeSumWorkload)).unwrap();
    let err = registry.register(Arc::new(RangeSumWorkload)).unwrap_err();
    assert!(err.to_string().contains("already registered"), "{err}");
}

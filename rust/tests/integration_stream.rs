//! Integration: cross-strategy equivalence at realistic sizes, failure
//! injection, and executor behaviour under the paper's workload shapes.

use stream_future::exec::{Executor, ExecutorConfig};
use stream_future::poly::{parse_polynomial, stream_times, Polynomial};
use stream_future::prelude::*;
use stream_future::sieve;
use stream_future::testkit::with_stack;
use stream_future::workload::{fateman_pair, fateman_pair_big};

#[test]
fn sieve_agrees_across_strategies_at_5000() {
    let oracle = sieve::eratosthenes(5_000);
    let lazy = with_stack(512, || sieve::primes(LazyEval, 5_000));
    assert_eq!(lazy, oracle);
    for workers in [1, 2, 4] {
        let eval = FutureEval::new(Executor::new(workers));
        let got = with_stack(512, move || sieve::primes(eval, 5_000));
        assert_eq!(got, oracle, "par({workers})");
    }
}

#[test]
fn fateman_product_agrees_across_strategies() {
    let (p, q) = fateman_pair(4, 6);
    let want = p.mul(&q);
    {
        let (p, q) = (p.clone(), q.clone());
        let got = with_stack(512, move || stream_times(&LazyEval, &p, &q));
        assert_eq!(got, want);
    }
    for workers in [1, 3] {
        let (p, q) = (p.clone(), q.clone());
        let eval = FutureEval::new(Executor::new(workers));
        let got = with_stack(512, move || stream_times(&eval, &p, &q));
        assert_eq!(got, want, "par({workers})");
    }
}

#[test]
fn big_coefficients_survive_the_pipeline() {
    let (p, q) = fateman_pair_big(3, 5, 100_000_000_001);
    let want = p.mul(&q);
    let eval = FutureEval::new(Executor::new(2));
    let got = with_stack(512, move || stream_times(&eval, &p, &q));
    assert_eq!(got, want);
    // The leading coefficient carries the squared factor.
    let (_, c) = want.leading().unwrap();
    assert_eq!(c.to_string(), "10000000000200000000001"); // (10^11+1)^2
}

#[test]
fn panic_deep_in_future_stream_propagates_to_consumer() {
    let eval = FutureEval::new(Executor::new(2));
    let s = Stream::range(eval, 0, 100).map_elems(|&x| {
        if x == 57 {
            panic!("injected failure at 57");
        }
        x
    });
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| s.to_vec()));
    assert!(res.is_err(), "failure must reach the forcing thread");
}

#[test]
fn executor_survives_poisoned_workload_and_serves_again() {
    let ex = Executor::new(2);
    let eval = FutureEval::new(ex.clone());
    let s = Stream::range(eval.clone(), 0, 20)
        .map_elems(|&x| if x == 5 { panic!("boom") } else { x });
    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| s.to_vec()));
    // Same pool keeps working.
    let ok = Stream::range(eval, 100, 110).to_vec();
    assert_eq!(ok, (100..110).collect::<Vec<_>>());
}

#[test]
fn par1_never_deadlocks_on_nested_dependencies() {
    // The killer case for naive pools: a stream whose map stages force
    // other suspensions, on a single worker. Managed blocking must keep
    // it live. (The paper's plus() does exactly this.)
    let a = parse_polynomial::<i64>("x^3 + x^2 + x + 1", &["x"]).unwrap();
    let b = parse_polynomial::<i64>("x^3 - x^2 + x - 1", &["x"]).unwrap();
    let eval = FutureEval::new(Executor::new(1));
    let got = with_stack(64, move || stream_times(&eval, &a, &b));
    let a2 = parse_polynomial::<i64>("x^3 + x^2 + x + 1", &["x"]).unwrap();
    let b2 = parse_polynomial::<i64>("x^3 - x^2 + x - 1", &["x"]).unwrap();
    assert_eq!(got, a2.mul(&b2));
}

#[test]
fn cancellation_heavy_merge_under_future() {
    // p + (-p) exercises the paper's "unavoidable Await.result" branch on
    // every single term.
    let (p, _) = fateman_pair(3, 4);
    let neg = p.neg();
    let eval = FutureEval::new(Executor::new(2));
    let sum = with_stack(512, move || {
        use stream_future::poly::{plus, PolyStream};
        let a: PolyStream<i64, _> = Stream::from_vec(eval.clone(), p.terms().to_vec());
        let b: PolyStream<i64, _> = Stream::from_vec(eval.clone(), neg.terms().to_vec());
        plus(&a, &b).to_vec()
    });
    assert!(sum.is_empty(), "total cancellation must produce the empty stream");
}

#[test]
fn custom_executor_config_is_respected() {
    let mut cfg = ExecutorConfig::with_parallelism(3);
    cfg.name = "itest".into();
    let ex = Executor::with_config(cfg);
    assert_eq!(ex.parallelism(), 3);
    let eval = FutureEval::new(ex.clone());
    let v = Stream::range(eval, 0, 1000).map_elems(|x| x + 1).to_vec();
    assert_eq!(v.len(), 1000);
    let stats = ex.stats();
    assert!(stats.tasks_executed >= 1000);
}

#[test]
fn chunked_sieve_large_scale_cross_strategy() {
    let oracle = sieve::eratosthenes(60_000); // the paper's primes_x3 size
    assert_eq!(oracle.len(), 6_057);
    let got = sieve::chunked_primes(LazyEval, 60_000, 1024);
    assert_eq!(got, oracle);
    let eval = FutureEval::new(Executor::new(4));
    let got = sieve::chunked_primes(eval, 60_000, 1024);
    assert_eq!(got, oracle);
}

#[test]
fn polynomial_display_roundtrip_through_parser() {
    let p: Polynomial<i64> =
        parse_polynomial("3*x^2*y - 4*z + 7", &["x", "y", "z"]).unwrap();
    let q: Polynomial<i64> =
        parse_polynomial(&p.to_string().replace("+ -", "- "), &["x", "y", "z"]).unwrap();
    assert_eq!(p, q);
}

//! Systematic interleaving checks for the lock-free core, via the
//! in-tree loom-lite explorer (`testkit::model`). Requires the `model`
//! cargo feature:
//!
//! ```text
//! cargo test --features model --test model_check
//! ```
//!
//! Every scenario here is bounded under *any* schedule (bounded steal
//! attempts, bounded polls) — the explorer's DFS default policy is
//! "continue the current thread", so an unbounded spin would never
//! terminate a run. Whole-run invariants (exactly-once claim ledgers)
//! run as post-run checks on the controller thread.
//!
//! The suites assert floors on *distinct* schedules explored; summed
//! across the file the floors exceed the 10k acceptance floor
//! (3800 + 1900 + 1500 + 1500 + 1000 + 500 + 100 + 20 + 15 = 10335).
//! The floors are sized to each scenario's trace space: the deque
//! scenarios have astronomically many interleavings (random traces are
//! effectively collision-free), while the two-thread `Fut` scenarios
//! have spaces of only tens to hundreds of traces and carry token
//! floors.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use stream_future::testkit::model::deque::ModelChaseLev;
use stream_future::testkit::model::fut::{ModelFut, ModelFutPromise, PANICKED, READY};
use stream_future::testkit::model::racy::{BrokenPublish, RacyCounter};
use stream_future::testkit::model::{
    explore_dfs, explore_random, replay_seed, ModelAtomicUsize, Scenario,
};

/// Exactly-once claim ledger: one slot per job id; claiming twice
/// panics inside the claiming thread (duplication is caught at the
/// exact step it happens, with the trace to replay it).
struct Claims {
    slots: Vec<ModelAtomicUsize>,
}

impl Claims {
    fn new(jobs: usize) -> Arc<Self> {
        Arc::new(Claims { slots: (0..=jobs).map(|_| ModelAtomicUsize::new(0)).collect() })
    }

    fn claim(&self, job: u64) {
        let prev = self.slots[job as usize].fetch_add(1, Ordering::SeqCst);
        assert!(prev == 0, "job {job} claimed twice");
    }

    /// Post-run: every job id in `1..=jobs` claimed exactly once.
    fn assert_complete(&self) {
        for (job, slot) in self.slots.iter().enumerate().skip(1) {
            let n = slot.load(Ordering::SeqCst);
            assert!(n == 1, "job {job} claimed {n} times (loss or duplication)");
        }
    }
}

/// 1 owner (push/pop/push/drain) + 2 thieves (bounded steal attempts)
/// over a deque that never grows: the core no-loss/no-duplication
/// scenario.
fn owner_two_thieves() -> Scenario {
    const JOBS: usize = 5;
    let deque = Arc::new(ModelChaseLev::new(8, 0));
    let claims = Claims::new(JOBS);
    let mut threads: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
    {
        let (d, c) = (Arc::clone(&deque), Arc::clone(&claims));
        threads.push(Box::new(move || {
            for j in 1..=3u64 {
                d.push(j);
            }
            if let Some(j) = d.pop() {
                c.claim(j);
            }
            for j in 4..=JOBS as u64 {
                d.push(j);
            }
            for j in d.drain() {
                c.claim(j);
            }
        }));
    }
    for _ in 0..2 {
        let (d, c) = (Arc::clone(&deque), Arc::clone(&claims));
        threads.push(Box::new(move || {
            for _ in 0..3 {
                if let Some(j) = d.steal() {
                    c.claim(j);
                }
            }
        }));
    }
    Scenario::with_check(threads, move || claims.assert_complete())
}

#[test]
fn deque_no_loss_no_duplication_random() {
    let report = explore_random(0xD00D_F00D, 4000, owner_two_thieves);
    assert!(report.failure.is_none(), "model failure: {:?}", report.failure);
    assert!(
        report.distinct >= 3800,
        "expected >= 3800 distinct schedules, got {}",
        report.distinct
    );
}

#[test]
fn deque_no_loss_no_duplication_dfs() {
    let report = explore_dfs(2, 2500, owner_two_thieves);
    assert!(report.failure.is_none(), "model failure: {:?}", report.failure);
    assert!(
        report.distinct >= 1000,
        "expected >= 1000 distinct DFS schedules, got {}",
        report.distinct
    );
}

/// Grow-under-steal across the u64 index boundary: base capacity 2,
/// indices starting at u64::MAX - 2, three thieves racing the owner
/// through two grows. The thief-side `freed == 0` assertion turns a
/// retire-protocol bug into a deterministic finding.
fn grow_under_steal_wraparound() -> Scenario {
    const JOBS: usize = 6;
    let deque = Arc::new(ModelChaseLev::with_start_index(u64::MAX - 2, 2, 2));
    let claims = Claims::new(JOBS);
    let mut threads: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
    {
        let (d, c) = (Arc::clone(&deque), Arc::clone(&claims));
        threads.push(Box::new(move || {
            for j in 1..=JOBS as u64 {
                d.push(j);
            }
            for j in d.drain() {
                c.claim(j);
            }
        }));
    }
    for _ in 0..2 {
        let (d, c) = (Arc::clone(&deque), Arc::clone(&claims));
        threads.push(Box::new(move || {
            for _ in 0..3 {
                if let Some(j) = d.steal() {
                    c.claim(j);
                }
            }
        }));
    }
    Scenario::with_check(threads, move || claims.assert_complete())
}

#[test]
fn deque_grow_under_steal_wraparound_random() {
    let report = explore_random(0xCAFE_BABE, 2000, grow_under_steal_wraparound);
    assert!(report.failure.is_none(), "model failure: {:?}", report.failure);
    assert!(
        report.distinct >= 1900,
        "expected >= 1900 distinct schedules, got {}",
        report.distinct
    );
}

#[test]
fn deque_grow_under_steal_wraparound_dfs() {
    let report = explore_dfs(2, 1500, grow_under_steal_wraparound);
    assert!(report.failure.is_none(), "model failure: {:?}", report.failure);
    assert!(report.distinct >= 500, "got {}", report.distinct);
}

/// Steal-half linearizability: the batch a thief takes must be the
/// oldest jobs in strict FIFO order (each single steal claims the
/// then-oldest slot), and globally each job is claimed exactly once.
fn steal_half_linearizable() -> Scenario {
    const JOBS: usize = 8;
    let deque = Arc::new(ModelChaseLev::new(8, 0));
    let claims = Claims::new(JOBS);
    let mut threads: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
    {
        let (d, c) = (Arc::clone(&deque), Arc::clone(&claims));
        threads.push(Box::new(move || {
            for j in 1..=JOBS as u64 {
                d.push(j);
            }
            if let Some(j) = d.pop() {
                c.claim(j);
            }
            for j in d.drain() {
                c.claim(j);
            }
        }));
    }
    for _ in 0..2 {
        let (d, c) = (Arc::clone(&deque), Arc::clone(&claims));
        threads.push(Box::new(move || {
            let batch = d.steal_half();
            for w in batch.windows(2) {
                assert!(
                    w[0] < w[1],
                    "steal-half batch out of FIFO order: {batch:?}"
                );
            }
            for &j in &batch {
                c.claim(j);
            }
        }));
    }
    Scenario::with_check(threads, move || claims.assert_complete())
}

#[test]
fn deque_steal_half_linearizability_random() {
    let report = explore_random(0x5EA1, 1600, steal_half_linearizable);
    assert!(report.failure.is_none(), "model failure: {:?}", report.failure);
    assert!(
        report.distinct >= 1500,
        "expected >= 1500 distinct schedules, got {}",
        report.distinct
    );
}

/// Completer racing two registering waiters: delivery must happen
/// exactly once per waiter whichever side of the registration/sweep
/// race wins.
fn fut_exactly_once() -> Scenario {
    let fut = Arc::new(ModelFut::new(2));
    let mut threads: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
    {
        let f = Arc::clone(&fut);
        threads.push(Box::new(move || {
            assert!(f.try_start());
            f.complete(42);
        }));
    }
    for i in 0..2usize {
        let f = Arc::clone(&fut);
        threads.push(Box::new(move || f.on_complete(i)));
    }
    let f = Arc::clone(&fut);
    Scenario::with_check(threads, move || {
        assert_eq!(f.state(), READY);
        assert_eq!(f.value(), 42);
        for i in 0..2 {
            let n = f.delivery_count(i);
            assert!(n == 1, "waiter {i} delivered {n} times");
        }
    })
}

#[test]
fn fut_exactly_once_delivery_random() {
    let report = explore_random(0xF07, 3000, fut_exactly_once);
    assert!(report.failure.is_none(), "model failure: {:?}", report.failure);
    assert!(
        report.distinct >= 1500,
        "expected >= 1500 distinct schedules, got {}",
        report.distinct
    );
}

#[test]
fn fut_exactly_once_delivery_dfs() {
    let report = explore_dfs(2, 1500, fut_exactly_once);
    assert!(report.failure.is_none(), "model failure: {:?}", report.failure);
    assert!(report.distinct >= 100, "got {}", report.distinct);
}

/// The promise drop-guard racing a waiter: abandoning the promise
/// (production "runner died") must still deliver exactly once, as
/// PANICKED.
fn fut_promise_drop() -> Scenario {
    let fut = Arc::new(ModelFut::new(1));
    let mut threads: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
    {
        let f = Arc::clone(&fut);
        threads.push(Box::new(move || {
            let promise = ModelFutPromise::claim(Arc::clone(&f)).expect("sole claimant");
            // Dropped without complete(): the guard must panick-complete.
            drop(promise);
        }));
    }
    {
        let f = Arc::clone(&fut);
        threads.push(Box::new(move || f.on_complete(0)));
    }
    let f = Arc::clone(&fut);
    Scenario::with_check(threads, move || {
        assert_eq!(f.state(), PANICKED);
        let n = f.delivery_count(0);
        assert!(n == 1, "waiter delivered {n} times");
    })
}

#[test]
fn fut_promise_drop_guard_random() {
    let report = explore_random(0xDEAD_90DE, 1200, fut_promise_drop);
    assert!(report.failure.is_none(), "model failure: {:?}", report.failure);
    // The two-thread drop scenario has a trace space of only dozens of
    // interleavings — the floor asserts coverage of it, not bulk.
    assert!(report.distinct >= 20, "got {}", report.distinct);
}

/// Publication order through a raw polling observer (no callback
/// machinery): any observer that sees READY must see the value.
fn fut_publication_order() -> Scenario {
    let fut = Arc::new(ModelFut::new(0));
    let mut threads: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
    {
        let f = Arc::clone(&fut);
        threads.push(Box::new(move || {
            assert!(f.try_start());
            f.complete(7);
        }));
    }
    {
        let f = Arc::clone(&fut);
        threads.push(Box::new(move || {
            for _ in 0..3 {
                if f.state() >= READY {
                    assert_eq!(f.value(), 7, "READY observed with unpublished value");
                    break;
                }
            }
        }));
    }
    Scenario::new(threads)
}

#[test]
fn fut_publication_order_random() {
    let report = explore_random(0x9B, 900, fut_publication_order);
    assert!(report.failure.is_none(), "model failure: {:?}", report.failure);
    // Tiny trace space (two threads, ~9 steps): token floor.
    assert!(report.distinct >= 15, "got {}", report.distinct);
}

// ---------------------------------------------------------------------
// The checker checked: deliberately racy fixtures must FAIL, and a
// random-mode failure must replay byte-identically from its seed.
// ---------------------------------------------------------------------

fn racy_counter_scenario() -> Scenario {
    let counter = Arc::new(RacyCounter::new());
    let mut threads: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
    for _ in 0..2 {
        let c = Arc::clone(&counter);
        threads.push(Box::new(move || c.increment()));
    }
    let c = Arc::clone(&counter);
    Scenario::with_check(threads, move || {
        assert_eq!(c.get(), 2, "lost update");
    })
}

fn broken_publish_scenario() -> Scenario {
    let pub_ = Arc::new(BrokenPublish::new());
    let mut threads: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
    {
        let p = Arc::clone(&pub_);
        threads.push(Box::new(move || p.complete(11)));
    }
    {
        let p = Arc::clone(&pub_);
        threads.push(Box::new(move || {
            for _ in 0..3 {
                if let Some(v) = p.poll() {
                    assert!(v != 0, "observed READY with unpublished value");
                    break;
                }
            }
        }));
    }
    Scenario::new(threads)
}

#[test]
fn racy_counter_found_and_replays_byte_identically() {
    let report = explore_random(0xBAD_5EED, 2000, racy_counter_scenario);
    let failure = report
        .failure
        .expect("the checker must find the lost update in a racy counter");
    let seed = failure.seed.expect("random-mode failures carry a seed");
    // Replaying the printed seed must reproduce the identical failing
    // interleaving: same decision trace, same message, byte for byte.
    let replayed = replay_seed(seed, racy_counter_scenario);
    let refailure = replayed.failure.expect("replay must fail again");
    assert_eq!(refailure, failure, "replay diverged from the original failure");
}

#[test]
fn broken_publish_found_by_dfs_and_random() {
    let dfs = explore_dfs(2, 4000, broken_publish_scenario);
    assert!(
        dfs.failure.is_some(),
        "DFS must find the inverted publication order (explored {})",
        dfs.schedules
    );
    let random = explore_random(0x1CE, 2000, broken_publish_scenario);
    let failure = random
        .failure
        .expect("random exploration must find the inverted publication order");
    let seed = failure.seed.expect("random-mode failures carry a seed");
    let replayed = replay_seed(seed, broken_publish_scenario);
    assert_eq!(replayed.failure, Some(failure), "replay diverged");
}

//! Seeded chaos suite for the fault-contained job lifecycle (requires
//! `--features chaos`).
//!
//! Every scenario injects a *known* schedule of faults through the
//! `faulty` workload or the ingress runner-fault hook and then checks
//! the lifecycle invariant: **every submitted job resolves to exactly
//! one terminal outcome** — a verified `ok`, a `verified=false` ok, or
//! one machine-parseable `err` line from the documented taxonomy
//! (`panicked` / `timeout` / `rejected` / abandoned) — with the wire
//! totals reconciling *exactly* against the `jobs.panicked` /
//! `jobs.timed_out` / `jobs.retried` counters, no runner thread dying
//! permanently, and graceful drain resolving every outstanding ticket.
//!
//! CI runs the suite under both `SFUT_DEQUE=chase_lev` and `=locked`
//! and uploads the reconciliation dump the concurrent-TCP scenario
//! writes (`CHAOS_report.json`).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use stream_future::config::Config;
use stream_future::coordinator::{serve, JobRequest, Pipeline, TcpServer};
use stream_future::exec::DequeKind;
use stream_future::testkit::wire::{parse_err_line, ErrLine};
use stream_future::workload::{register_chaos_workloads, WorkloadRegistry};

fn chaos_pipeline(cfg: Config) -> Pipeline {
    let mut reg = WorkloadRegistry::builtin();
    register_chaos_workloads(&mut reg).unwrap();
    Pipeline::with_registry(cfg, reg).unwrap()
}

fn base_config() -> Config {
    let mut cfg = Config::default();
    cfg.primes_n = 300;
    cfg.fateman_degree = 2;
    cfg.chunk_size = 16;
    cfg.use_kernel = false;
    cfg.shards = 1;
    cfg.shard_parallelism = 1;
    cfg.dispatchers = 1;
    cfg.queue_depth = 8;
    cfg
}

fn counter(p: &Pipeline, name: &str) -> u64 {
    p.metrics().snapshot().counters.get(name).copied().unwrap_or(0)
}

fn session(addr: std::net::SocketAddr, script: &str) -> Vec<String> {
    let mut sock = TcpStream::connect(addr).unwrap();
    sock.write_all(script.as_bytes()).unwrap();
    sock.flush().unwrap();
    sock.shutdown(std::net::Shutdown::Write).unwrap();
    BufReader::new(sock).lines().map(|l| l.unwrap()).collect()
}

/// A workload panic costs exactly one job: the wire gets the documented
/// `err panicked …` line (reason last — it contains spaces), the runner
/// thread survives to serve the next request, and nothing retried.
#[test]
fn panic_is_contained_to_one_job_and_machine_parseable() {
    let p = chaos_pipeline(base_config());
    let script = "run faulty(fail_mode=panic,seed=7) seq\nrun primes seq\n";
    let mut out = Vec::new();
    let jobs = serve(&p, script.as_bytes(), &mut out).unwrap();
    let out = String::from_utf8(out).unwrap();
    assert_eq!(jobs, 1, "{out}");
    let panicked = out
        .lines()
        .filter_map(parse_err_line)
        .find_map(|e| match e {
            ErrLine::Panicked { workload, mode, reason } => Some((workload, mode, reason)),
            _ => None,
        })
        .unwrap_or_else(|| panic!("panicked line: {out}"));
    assert_eq!(panicked.0, "faulty(fail_mode=panic,seed=7)", "{out}");
    assert_eq!(panicked.1, "seq", "{out}");
    assert_eq!(panicked.2, "injected panic (attempt 0 seed 7)", "{out}");
    // The single runner that caught the panic served the follow-up job:
    // containment, not survival-by-respawn.
    assert!(out.contains("ok workload=primes"), "{out}");
    assert_eq!(counter(&p, "jobs.panicked"), 1);
    assert_eq!(counter(&p, "jobs.retried"), 0);
    // Caught at the workload boundary — the runner-level guard never
    // had to fire.
    assert_eq!(counter(&p, "ingress.runner_recovered"), 0);
}

/// Transient panics retry with backoff onto a fresh attempt and
/// recover: every job ends verified, with the panic and retry counters
/// agreeing exactly on how many first attempts died.
#[test]
fn transient_panic_retries_and_recovers() {
    let mut cfg = base_config();
    cfg.retry_max = 1;
    cfg.retry_backoff_ms = 1;
    let p = chaos_pipeline(cfg);
    for seed in 0..3u64 {
        let spec = format!("faulty(fail_mode=panic,fail_nth=1,seed={seed}) seq");
        let res = p.run(&JobRequest::parse(&spec).unwrap()).unwrap();
        assert!(res.verified, "retry must recover seed {seed}");
    }
    assert_eq!(counter(&p, "jobs.panicked"), 3);
    assert_eq!(counter(&p, "jobs.retried"), 3);
    assert_eq!(counter(&p, "jobs.completed"), 3);
}

/// The per-job deadline reaps a stalled workload through the
/// cooperative cancel token: terminal `timeout` outcome naming the
/// deadline, long before the stall's own 60 s give-up.
#[test]
fn deadline_reaps_stalled_job_as_timeout() {
    let p = chaos_pipeline(base_config());
    let spec = "faulty(deadline_ms=120,fail_mode=stall,stall_ms=60000) seq";
    let started = Instant::now();
    let err = p.run(&JobRequest::parse(spec).unwrap()).unwrap_err().to_string();
    assert!(
        started.elapsed() < Duration::from_secs(30),
        "deadline must cut the stall short, not wait it out"
    );
    // Error Display forms carry the documented grammar minus the `err `
    // tag; the shared parser accepts both.
    match parse_err_line(&err) {
        Some(ErrLine::JobTimeout { workload, mode, deadline_ms }) => {
            assert!(workload.starts_with("faulty"), "{err}");
            assert_eq!(mode, "seq", "{err}");
            assert_eq!(deadline_ms, 120, "{err}");
        }
        other => panic!("expected a job-timeout line, got {other:?}: {err}"),
    }
    assert_eq!(counter(&p, "jobs.timed_out"), 1);
}

/// A wrong result is a *deterministic* fault: it reports
/// `verified=false` and must not burn retry budget (retrying would
/// produce the same wrong answer).
#[test]
fn wrong_result_is_not_transient_and_never_retries() {
    let mut cfg = base_config();
    cfg.retry_max = 2;
    cfg.retry_backoff_ms = 1;
    let p = chaos_pipeline(cfg);
    let res = p.run(&JobRequest::parse("faulty(fail_mode=wrong_result,seed=5) seq").unwrap());
    let res = res.unwrap();
    assert!(!res.verified);
    assert_eq!(counter(&p, "jobs.retried"), 0);
    assert_eq!(counter(&p, "jobs.panicked"), 0);
}

/// Repeated panics open the per-workload circuit breaker: further
/// submissions answer up front with the documented rejected line (no
/// queue capacity taken), other workloads keep flowing, and the
/// `breaker.faulty.open` gauge flips.
#[test]
fn breaker_quarantines_workload_after_repeated_panics() {
    let mut cfg = base_config();
    cfg.breaker_threshold = 2;
    let p = chaos_pipeline(cfg);
    for _ in 0..2 {
        let err = p.run(&JobRequest::parse("faulty(fail_mode=panic) seq").unwrap()).unwrap_err();
        let parsed = parse_err_line(&err.to_string());
        assert!(
            matches!(parsed, Some(ErrLine::Panicked { ref workload, .. })
                if workload.starts_with("faulty")),
            "{err:#}"
        );
    }
    let mut out = Vec::new();
    serve(&p, "run faulty(fail_mode=none) seq\nrun primes seq\n".as_bytes(), &mut out).unwrap();
    let out = String::from_utf8(out).unwrap();
    let reason = out
        .lines()
        .filter_map(parse_err_line)
        .find_map(|e| match e {
            ErrLine::Rejected { workload, reason, .. } if workload.starts_with("faulty") => {
                Some(reason)
            }
            _ => None,
        })
        .unwrap_or_else(|| panic!("breaker rejection line: {out}"));
    assert_eq!(
        reason, "breaker open: workload faulty quarantined after repeated panics",
        "{out}"
    );
    assert!(out.contains("ok workload=primes"), "healthy workloads keep flowing: {out}");
    assert_eq!(p.metrics().snapshot().gauges["breaker.faulty.open"], 1);
    // Direct submissions see the same quarantine.
    match p.submit(&JobRequest::parse("faulty(fail_mode=none) seq").unwrap()) {
        Err(e) => assert!(e.to_string().contains("breaker open"), "{e}"),
        Ok(_) => panic!("expected breaker rejection, got a ticket"),
    }
}

/// Seeded runner-level faults (the hook panics *outside* the workload
/// boundary): exactly the scheduled jobs resolve as abandoned tickets
/// via the promise drop-guard, the recovery counter matches, and the
/// runner thread keeps serving afterwards.
#[test]
fn injected_runner_faults_abandon_exactly_their_jobs_and_recover() {
    let p = chaos_pipeline(base_config());
    p.ingress().chaos_set_runner_panic_every(2);
    let req = JobRequest::parse("primes seq").unwrap();
    let tickets: Vec<_> = (0..4).map(|_| p.submit(&req).unwrap()).collect();
    let mut oks = 0u32;
    let mut abandoned = 0u32;
    for t in &tickets {
        match t.wait() {
            Ok(res) => {
                assert!(res.verified);
                oks += 1;
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(msg.contains("job ticket abandoned"), "{msg}");
                assert!(msg.contains("promise dropped before completion"), "{msg}");
                abandoned += 1;
            }
        }
    }
    assert_eq!((oks, abandoned), (2, 2), "every-2nd schedule: half abandoned");
    assert_eq!(counter(&p, "ingress.runner_recovered"), 2);
    // Injection off: the surviving runner serves normally.
    p.ingress().chaos_set_runner_panic_every(0);
    assert!(p.run(&req).unwrap().verified);
}

/// Graceful drain under pending faults: queued jobs (including ones
/// scheduled to panic once and recover on retry) all execute during
/// shutdown — every ticket resolves, none hang, none are abandoned.
#[test]
fn graceful_drain_resolves_every_ticket() {
    let mut cfg = base_config();
    cfg.retry_max = 1;
    cfg.retry_backoff_ms = 1;
    let p = chaos_pipeline(cfg);
    p.ingress().set_runner_hold(0, true);
    let specs = [
        "faulty(fail_mode=panic,fail_nth=1,seed=1) seq",
        "faulty(fail_mode=none,seed=2) seq",
        "primes seq",
        "faulty(fail_mode=panic,fail_nth=1,seed=3) seq",
    ];
    let tickets: Vec<_> =
        specs.iter().map(|s| p.submit(&JobRequest::parse(s).unwrap()).unwrap()).collect();
    assert!(tickets.iter().all(|t| !t.is_ready()), "hold keeps the queue parked");
    // Dropping the last handle shuts the ingress down; the drain clears
    // holds and executes (and where scheduled, retries) every job.
    drop(p);
    for (t, spec) in tickets.iter().zip(specs) {
        let res = t.wait().unwrap_or_else(|e| panic!("{spec} not resolved by drain: {e:#}"));
        assert!(res.verified, "{spec}");
    }
}

/// The headline invariant, end-to-end over TCP: four concurrent
/// sessions mixing scripted panics, stalls-under-deadline, wrong
/// results, and healthy jobs. Every request gets exactly one response
/// line from the documented grammar, and the wire totals reconcile
/// *exactly* with the lifecycle counters. Writes `CHAOS_report.json`
/// (the CI artifact) after the asserts pass.
#[test]
fn concurrent_sessions_reconcile_faults_exactly() {
    let mut cfg = base_config();
    cfg.shards = 2;
    cfg.shard_parallelism = 2;
    cfg.dispatchers = 2;
    cfg.queue_depth = 16;
    let p = Arc::new(chaos_pipeline(cfg));
    let server = TcpServer::start(Arc::clone(&p), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();

    let sessions = 4usize;
    let commands_per_session = 6usize;
    let all_lines: Vec<String> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..sessions)
            .map(|i| {
                s.spawn(move || {
                    let script = format!(
                        "run faulty(fail_mode=panic,seed={i}) seq\n\
                         run faulty(fail_mode=none,seed={i}) seq\n\
                         run primes seq\n\
                         run faulty(fail_mode=wrong_result,seed={i}) seq\n\
                         run faulty(deadline_ms=150,fail_mode=stall,stall_ms=60000) seq\n\
                         run primes par(2)\n"
                    );
                    session(addr, &script)
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });

    let total = sessions * commands_per_session;
    assert_eq!(all_lines.len(), total, "exactly one terminal line per request: {all_lines:?}");
    let mut oks = 0u64;
    let mut wrongs = 0u64;
    let mut panics = 0u64;
    let mut timeouts = 0u64;
    for line in &all_lines {
        if line.starts_with("ok ") {
            oks += 1;
            if line.contains("verified=false") {
                assert!(line.contains("workload=faulty(fail_mode=wrong_result"), "{line}");
                wrongs += 1;
            }
        } else {
            match parse_err_line(line) {
                Some(ErrLine::Panicked { workload, reason, .. }) => {
                    assert!(workload.starts_with("faulty"), "{line}");
                    assert!(reason.starts_with("injected panic"), "{line}");
                    panics += 1;
                }
                Some(ErrLine::JobTimeout { workload, deadline_ms, .. }) => {
                    assert!(workload.starts_with("faulty"), "{line}");
                    assert_eq!(deadline_ms, 150, "{line}");
                    timeouts += 1;
                }
                other => panic!(
                    "response line outside the documented grammar: {line} (parsed: {other:?})"
                ),
            }
        }
    }
    assert_eq!(oks, (4 * sessions) as u64, "{all_lines:?}");
    assert_eq!(wrongs, sessions as u64, "{all_lines:?}");
    assert_eq!(panics, sessions as u64, "{all_lines:?}");
    assert_eq!(timeouts, sessions as u64, "{all_lines:?}");

    // Wire ↔ counter reconciliation, exact.
    let snap = p.metrics().snapshot();
    assert_eq!(snap.counters["jobs.completed"], oks);
    assert_eq!(snap.counters["jobs.panicked"], panics);
    assert_eq!(snap.counters["jobs.timed_out"], timeouts);
    assert_eq!(snap.counters.get("jobs.retried").copied().unwrap_or(0), 0);
    assert_eq!(snap.counters.get("ingress.runner_recovered").copied().unwrap_or(0), 0);
    assert_eq!(snap.counters["ingress.submitted"], total as u64);
    assert_eq!(snap.counters["ingress.admitted"], total as u64);
    assert_eq!(snap.gauges["ingress.queue_depth"], 0);
    // No runner died permanently: the same pipeline still serves.
    assert!(p.run(&JobRequest::parse("primes seq").unwrap()).unwrap().verified);

    let json = format!(
        "{{\n  \"suite\": \"chaos_lifecycle\",\n  \"profile\": \"{}\",\n  \"deque\": \"{}\",\n  \
         \"sessions\": {sessions},\n  \"requests\": {total},\n  \
         \"injected\": {{ \"panic\": {sessions}, \"stall\": {sessions}, \
         \"wrong_result\": {sessions} }},\n  \
         \"observed\": {{ \"ok\": {oks}, \"verified_false\": {wrongs}, \
         \"panicked\": {panics}, \"timed_out\": {timeouts} }},\n  \
         \"counters\": {{ \"jobs_completed\": {}, \"jobs_panicked\": {}, \
         \"jobs_timed_out\": {}, \"jobs_retried\": 0, \"runner_recovered\": 0 }},\n  \
         \"reconciled\": true\n}}\n",
        if cfg!(debug_assertions) { "debug" } else { "release" },
        DequeKind::default_kind().label(),
        snap.counters["jobs.completed"],
        snap.counters["jobs.panicked"],
        snap.counters["jobs.timed_out"],
    );
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("CHAOS_report.json");
    std::fs::write(&out, json).expect("writing chaos reconciliation report");
}

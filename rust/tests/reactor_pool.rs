//! Reactor-pool invariants over real sockets: accept fanout spreads
//! sessions across reactors (deterministic round-robin under in-process
//! handoff, kernel balancing under `SO_REUSEPORT`), every session stays
//! pinned to the reactor that adopted it (observed through the
//! per-reactor `wire.<r>.*` shadow counters, which must also reconcile
//! with the totals), the job lifecycle holds under each readiness
//! backend with a multi-reactor pool, and pool shutdown drains parked
//! waiters then joins every reactor thread.
//!
//! Frame-level protocol conformance lives in `framed_wire.rs`; this
//! suite is about the pool itself.
#![cfg(unix)]

use std::sync::Arc;
use std::time::{Duration, Instant};

use stream_future::config::{Config, PollerKind, WireProtocol};
use stream_future::coordinator::frame::FrameKind;
use stream_future::coordinator::{Pipeline, TcpServer};
use stream_future::testkit::wire::{parse_err_line, ErrLine, FramedClient, SubmitReply};

/// Smoke-sized pipeline with an explicit reactor count. `reuseport` is
/// off so accept fanout takes the in-process handoff path: round-robin
/// dispatch is deterministic, which the distribution assertions need.
fn pool_config(reactors: usize) -> Config {
    let mut cfg = Config::default();
    cfg.primes_n = 300;
    cfg.fateman_degree = 2;
    cfg.chunk_size = 16;
    cfg.use_kernel = false;
    cfg.shards = 1;
    cfg.shard_parallelism = 1;
    cfg.dispatchers = 1;
    cfg.reactors = reactors;
    cfg.reuseport = false;
    cfg
}

fn framed_server(cfg: Config) -> (Arc<Pipeline>, TcpServer) {
    let pipeline = Arc::new(Pipeline::new(cfg).unwrap());
    let server =
        TcpServer::start_wire(Arc::clone(&pipeline), "127.0.0.1:0", WireProtocol::Framed).unwrap();
    (pipeline, server)
}

fn counter(pipeline: &Pipeline, name: &str) -> u64 {
    pipeline.metrics().snapshot().counters.get(name).copied().unwrap_or(0)
}

fn test_pollers() -> Vec<PollerKind> {
    if cfg!(target_os = "linux") {
        vec![PollerKind::Poll, PollerKind::Epoll]
    } else {
        vec![PollerKind::Poll]
    }
}

fn wait_until(what: &str, mut pred: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !pred() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn submit_and_wait_ok(client: &mut FramedClient) {
    let SubmitReply::Ticket { id, .. } = client.submit("primes par(2)").unwrap() else {
        panic!("submit rejected");
    };
    let line = client.wait(id).unwrap();
    assert!(line.starts_with("ok "), "{line}");
}

/// Handoff fanout is strict round-robin: with 3 reactors, 9 sequential
/// connections land 3-3-3. Each `connect` completes the handshake, so
/// every session is adopted (and its pin counted) before the next one
/// reaches the dispatcher — the distribution is exact, not statistical.
#[test]
fn handoff_fanout_round_robins_sessions_across_reactors() {
    let (_pipeline, mut server) = framed_server(pool_config(3));
    let addr = server.local_addr();

    let clients: Vec<_> = (0..9).map(|_| FramedClient::connect(addr).unwrap()).collect();

    assert_eq!(server.sessions(), 9);
    assert_eq!(
        server.sessions_per_reactor(),
        vec![3, 3, 3],
        "handoff dispatch must round-robin exactly"
    );

    drop(clients);
    server.shutdown();
    assert_eq!(server.live_sessions(), 0, "shutdown must drain the pool");
}

/// A session is pinned for life: every frame it sends is decoded by the
/// one reactor that adopted it, visible as exactly one moving
/// `wire.<r>.frames_in` shadow — and the shadows must reconcile with
/// the `wire.frames_in` total the existing dashboards read.
#[test]
fn session_frames_stay_pinned_to_one_reactor() {
    let (pipeline, mut server) = framed_server(pool_config(3));
    let mut client = FramedClient::connect(server.local_addr()).unwrap();

    let shadow = |r: usize| counter(&pipeline, &format!("wire.{r}.frames_in"));
    let before: Vec<u64> = (0..3).map(shadow).collect();
    let total_before = counter(&pipeline, "wire.frames_in");

    for _ in 0..5 {
        submit_and_wait_ok(&mut client);
    }

    let deltas: Vec<u64> = (0..3).map(|r| shadow(r) - before[r]).collect();
    let total_delta = counter(&pipeline, "wire.frames_in") - total_before;
    assert_eq!(total_delta, 10, "5 submits + 5 waits, got {total_delta}");
    assert_eq!(
        deltas.iter().filter(|&&d| d > 0).count(),
        1,
        "one session must be read by exactly one reactor: {deltas:?}"
    );
    assert_eq!(
        deltas.iter().sum::<u64>(),
        total_delta,
        "per-reactor shadows must reconcile with the total: {deltas:?}"
    );

    drop(client);
    server.shutdown();
}

/// The full job lifecycle — several concurrent sessions, submit → wait
/// → verified ok — holds under every readiness backend with a
/// two-reactor pool, and the pool reaps sessions as clients disconnect.
#[test]
fn jobs_resolve_under_each_poller_with_two_reactors() {
    for poller in test_pollers() {
        let mut cfg = pool_config(2);
        cfg.poller = poller;
        let (_pipeline, mut server) = framed_server(cfg);
        let addr = server.local_addr();

        let mut clients: Vec<_> = (0..4).map(|_| FramedClient::connect(addr).unwrap()).collect();
        for client in &mut clients {
            submit_and_wait_ok(client);
            submit_and_wait_ok(client);
        }
        assert_eq!(server.sessions(), 4, "poller {poller:?}");
        assert_eq!(server.sessions_per_reactor(), vec![2, 2], "poller {poller:?}");

        drop(clients);
        wait_until("disconnected sessions to be reaped", || server.live_sessions() == 0);
        server.shutdown();
    }
}

/// Pool shutdown is a drain, not an abort: a waiter parked on a job the
/// held shard can never finish still gets its final well-formed
/// `err closed` frame, every reactor thread joins (self-pipe fds close
/// with them), and shutdown is idempotent.
#[test]
fn pool_shutdown_drains_parked_waiter_and_joins_reactors() {
    let cfg = pool_config(2);
    let (pipeline, mut server) = framed_server(cfg);
    // Park the only shard so the waited job cannot resolve before
    // shutdown; the waiter must still get a final well-formed line.
    pipeline.ingress().set_runner_hold(0, true);

    let mut client = FramedClient::connect(server.local_addr()).unwrap();
    let SubmitReply::Ticket { id, .. } = client.submit("primes seq").unwrap() else {
        panic!("submit rejected");
    };
    let frames_seen = counter(&pipeline, "wire.frames_in");
    client.send_wait(id).unwrap();
    // The wait frame parks only once its reactor has decoded it; gate
    // shutdown on that so the drain path (not a pre-read close) answers.
    wait_until("the wait frame to be decoded", || {
        counter(&pipeline, "wire.frames_in") > frames_seen
    });

    server.shutdown();
    assert_eq!(server.live_sessions(), 0, "shutdown must join every reactor");

    let frames = client.drain().unwrap();
    let closed = frames.iter().any(|f| {
        f.kind == FrameKind::Err
            && FramedClient::line_of(f)
                .is_ok_and(|l| parse_err_line(&l) == Some(ErrLine::Closed { ticket: id }))
    });
    assert!(closed, "parked waiter must see the closed line, got {frames:?}");

    // Idempotent.
    server.shutdown();
    assert_eq!(server.live_sessions(), 0);
    pipeline.ingress().set_runner_hold(0, false);
}

/// `SO_REUSEPORT` fanout under a connection flood: the kernel spreads
/// 40 concurrent sessions over both listeners, every reactor adopts at
/// least one, and every job still resolves. Linux-only, like the
/// reuseport bind path itself.
#[cfg(target_os = "linux")]
#[test]
fn reuseport_fanout_spreads_a_connection_flood() {
    let mut cfg = pool_config(2);
    cfg.reuseport = true;
    let (_pipeline, mut server) = framed_server(cfg);
    let addr = server.local_addr();

    let flood = 40u64;
    let workers: Vec<_> = (0..flood)
        .map(|_| {
            std::thread::spawn(move || {
                let mut client = FramedClient::connect(addr).unwrap();
                submit_and_wait_ok(&mut client);
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }

    let per_reactor = server.sessions_per_reactor();
    assert_eq!(per_reactor.len(), 2);
    assert_eq!(per_reactor.iter().sum::<u64>(), flood, "{per_reactor:?}");
    // 40 distinct 4-tuples all hashing to one listener is a ~2^-39
    // event; a zero here means the group bind silently collapsed.
    assert!(per_reactor.iter().all(|&n| n > 0), "one-sided fanout: {per_reactor:?}");

    server.shutdown();
    assert_eq!(server.live_sessions(), 0);
}

//! Perf-lab integration surface: plan files parse and validate, the
//! grid runs end-to-end through the pipeline harness into the results
//! registry, legacy bench documents still read through the unified
//! schema, and the `sfut bench` / deprecated `check-bench` CLI contract
//! holds (spawned via `CARGO_BIN_EXE_sfut`).

use std::path::PathBuf;
use std::process::Command;

use stream_future::bench_harness::plan::{self, PlanBackend};
use stream_future::bench_harness::registry;
use stream_future::bench_harness::BenchReport;
use stream_future::config::Config;

fn temp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("sfut_bench_plan_{}_{name}", std::process::id()))
}

#[test]
fn plan_parses_axes_fixed_and_seed() {
    let text = "\
# a perf question as data
name = lab
backend = pipeline
seed = 42
samples = 2
warmup = 1
workload = primes
mode = par(2)

[axis]
shards = 1, 2
deque = chase_lev, locked

[fixed]
use_kernel = false
";
    let plan = plan::parse(text).unwrap();
    plan.validate().unwrap();
    assert_eq!(plan.name, "lab");
    assert_eq!(plan.backend, PlanBackend::Pipeline);
    assert_eq!(plan.seed, 42, "seed must survive the file roundtrip");
    assert_eq!(plan.samples, 2);
    assert_eq!(plan.grid_size(), 4);
    assert_eq!(plan.axes[0].key, "shards");
    assert_eq!(plan.axes[1].values, vec!["chase_lev".to_string(), "locked".to_string()]);
    assert_eq!(plan.fixed, vec![("use_kernel".to_string(), "false".to_string())]);
}

#[test]
fn plan_validation_rejects_bad_axes_and_empty_grids() {
    // Unknown config key as an axis.
    let err = plan::parse("name = x\n[axis]\nflux_capacitor = 1, 2\n")
        .unwrap()
        .validate()
        .unwrap_err();
    assert!(err.contains("flux_capacitor"), "{err}");

    // Known key, bad value — caught at validation, not mid-sweep.
    let err = plan::parse("name = x\n[axis]\ndeque = warp\n").unwrap().validate().unwrap_err();
    assert!(err.contains("warp") || err.contains("deque"), "{err}");

    // Unknown workload on the workload axis.
    let err = plan::parse("name = x\n[axis]\nworkload = primes, nonesuch\n")
        .unwrap()
        .validate()
        .unwrap_err();
    assert!(err.contains("unknown workload"), "{err}");

    // No axes at all: nothing to sweep.
    let err = plan::parse("name = x\n").unwrap().validate().unwrap_err();
    assert!(err.contains("no axes"), "{err}");

    // An axis with no values is a parse error naming its line.
    let err = plan::parse("name = x\n[axis]\nshards =\n").unwrap_err();
    assert!(err.contains("line 3"), "{err}");
}

#[test]
fn plan_parse_rejects_duplicates_with_line_numbers() {
    let err = plan::parse("name = a\nname = b\n").unwrap_err();
    assert!(err.contains("line 2") && err.contains("duplicate"), "{err}");
    let err = plan::parse("name = a\n[axis]\nshards = 1\nshards = 2\n").unwrap_err();
    assert!(err.contains("line 4") && err.contains("duplicate axis"), "{err}");
    let err = plan::parse("name = a\nwarp_factor = 9\n").unwrap_err();
    assert!(err.contains("line 2") && err.contains("unknown plan key"), "{err}");
}

#[test]
fn gate_set_parses_and_lists_three_targets() {
    let text = std::fs::read_to_string(plan::gate_set_path()).unwrap();
    let targets = plan::parse_gate_set(&text).unwrap();
    let names: Vec<&str> = targets.iter().map(|t| t.name.as_str()).collect();
    assert_eq!(names, vec!["pipeline", "ingress", "executor"]);
    assert_eq!(targets[0].baseline, "BENCH_pipeline.json");
    assert_eq!(targets[2].bench_target, "ablation_overhead");
    // The compiled-in fallback must match the committed file.
    assert_eq!(plan::parse_gate_set(plan::DEFAULT_GATE_SET).unwrap(), targets);
}

#[test]
fn run_plan_executes_a_two_axis_grid_into_the_registry() {
    let mut base = Config::default();
    for (key, value) in
        [("primes_n", "400"), ("use_kernel", "false"), ("shard_parallelism", "1")]
    {
        base.set(key, value).unwrap();
    }

    let text = "\
name = e2e
backend = pipeline
seed = 9
samples = 1
warmup = 0
workload = primes
mode = par(2)
clients = 1
jobs_per_client = 1

[axis]
shards = 1, 2
deque = chase_lev
";
    let plan = plan::parse(text).unwrap();
    let report = plan::run_plan(&plan, &base).unwrap();
    assert_eq!(report.grid_cells, 2);
    assert_eq!(report.points.len(), 2, "one pipeline point per grid cell");
    for point in &report.points {
        assert_eq!(point.label("workload"), Some("primes"));
        assert_eq!(point.label("deque"), Some("chase_lev"), "axis value stamped as label");
        assert!(point.metric("jobs_per_sec").is_some_and(|v| v > 0.0));
    }
    let shards: Vec<_> = report.points.iter().filter_map(|p| p.label("shards")).collect();
    assert_eq!(shards, vec!["1", "2"]);
    assert_eq!(report.provenance.seed, 9, "plan seed lands in provenance");
    assert!(!report.provenance.toolchain.is_empty());

    let reg = temp_path("e2e_registry.jsonl");
    let _ = std::fs::remove_file(&reg);
    assert_eq!(registry::append(&reg, &report).unwrap(), 2);
    let records = registry::read(&reg).unwrap();
    assert_eq!(records.len(), 2);
    assert_eq!(records[0].plan, "e2e");
    assert_eq!(records[0].backend, "pipeline");
    assert_eq!(records[0].provenance.seed, 9);
    let rendered = registry::render_report(&records, Some("e2e"));
    assert!(rendered.contains("plan e2e"), "{rendered}");
    assert!(rendered.contains("jobs_per_sec"), "{rendered}");
    let _ = std::fs::remove_file(&reg);
}

#[test]
fn bench_report_reads_legacy_flat_documents() {
    let legacy = r#"{"bench": "pipeline_throughput", "profile": "release", "scale": 0.05, "runs": [{"workload": "primes", "shards": 2, "jobs_per_sec": 120.5, "verified": true}]}"#;
    let report = BenchReport::parse(legacy).unwrap();
    assert_eq!(report.bench, "pipeline_throughput");
    assert_eq!(report.points.len(), 1);
    let p = &report.points[0];
    assert_eq!(p.label("workload"), Some("primes"));
    assert_eq!(p.label("shards"), Some("2"), "legacy numeric shards becomes a label");
    assert_eq!(p.metric("jobs_per_sec"), Some(120.5));
    assert_eq!(p.flags.get("verified"), Some(&true));
}

#[test]
fn check_bench_alias_forwards_to_the_gate_with_a_notice() {
    let doc = r#"{"bench": "pipeline_throughput", "profile": "release", "scale": 0.05, "runs": [{"workload": "primes", "shards": 1, "jobs_per_sec": 100}]}"#;
    let a = temp_path("alias_baseline.json");
    let b = temp_path("alias_current.json");
    std::fs::write(&a, doc).unwrap();
    std::fs::write(&b, doc).unwrap();

    // Deprecated spelling: still gates, exit 0, one-line notice.
    let out = Command::new(env!("CARGO_BIN_EXE_sfut"))
        .args(["check-bench", a.to_str().unwrap(), b.to_str().unwrap()])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout: {stdout}\nstderr: {stderr}");
    assert!(stderr.contains("deprecated"), "{stderr}");
    assert!(stdout.contains("bench gate PASSED"), "{stdout}");

    // New spelling: same verdict, no deprecation noise.
    let out = Command::new(env!("CARGO_BIN_EXE_sfut"))
        .args(["bench", "gate", "pipeline", a.to_str().unwrap(), b.to_str().unwrap()])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout: {stdout}\nstderr: {stderr}");
    assert!(!stderr.contains("deprecated"), "{stderr}");
    assert!(stdout.contains("bench gate PASSED"), "{stdout}");

    // An undeclared gate target is rejected up front.
    let out = Command::new(env!("CARGO_BIN_EXE_sfut"))
        .args(["bench", "gate", "warp", a.to_str().unwrap(), b.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown gate target"), "{stderr}");

    let _ = std::fs::remove_file(&a);
    let _ = std::fs::remove_file(&b);
}

#[test]
fn bench_list_gates_is_machine_readable() {
    let out =
        Command::new(env!("CARGO_BIN_EXE_sfut")).args(["bench", "list", "gates"]).output().unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "{stdout}");
    // ci/check_bench.sh splits these lines on whitespace.
    assert!(stdout.contains("pipeline BENCH_pipeline.json pipeline_throughput"), "{stdout}");
    assert!(stdout.contains("ingress BENCH_ingress.json ingress_wire"), "{stdout}");
    assert!(stdout.contains("executor BENCH_executor.json ablation_overhead"), "{stdout}");
}

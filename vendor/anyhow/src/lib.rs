//! Offline shim for the `anyhow` crate.
//!
//! The container this repo builds in has no crates.io access, so the
//! subset of `anyhow` the codebase actually uses is reimplemented here
//! and wired in as a path dependency. Covered surface:
//!
//! * [`Error`] — context-chain error value (`Display`, `{:#}` full chain,
//!   `Debug`), `From<E: std::error::Error>` so `?` converts foreign
//!   errors.
//! * [`Result<T>`] with the default error type.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on both `Result`
//!   and `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Not covered (unused by this repo): backtraces, downcasting, source()
//! chaining of live error values (sources are flattened to strings at
//! conversion time).

use std::fmt;

/// A formatted error with a chain of contexts. `chain[0]` is the
/// outermost (most recently attached) message, the last entry the root
/// cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (the `anyhow!` entry point).
    pub fn msg(message: impl fmt::Display) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    fn wrap(mut self, context: impl fmt::Display) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full context chain, outermost first.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// `Error` deliberately does NOT implement `std::error::Error`; that is
// what keeps this blanket conversion coherent (same trick as real
// anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures. Implemented for `Result` (any error
/// convertible to [`Error`]) and for `Option` (where `None` becomes an
/// error carrying only the context message).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = (Err(io_err()) as std::result::Result<(), std::io::Error>)
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("missing key").unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let e = anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }
}

//! Offline stand-in for the `xla` PJRT-bindings crate.
//!
//! Mirrors exactly the API surface `runtime::engine` uses. Only
//! [`PjRtClient::cpu`] is ever reached at runtime: it fails with a clean
//! error, the engine thread reports startup failure, and every caller
//! falls back to the pure-Rust block implementations. The remaining
//! types exist so the engine code typechecks; their method bodies are
//! unreachable (the client holds an uninhabited type, so no executable,
//! buffer, or literal can ever be constructed).
//!
//! To run real PJRT, point the `xla` path dependency in the workspace
//! `Cargo.toml` at the actual crate — the engine code compiles against
//! either.

use std::fmt;
use std::path::Path;

/// Uninhabited: proves the unreachable method bodies sound.
enum Never {}

/// The stub's only error: PJRT support is not really here.
#[derive(Debug)]
pub struct Unavailable;

impl fmt::Display for Unavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(
            "PJRT support not compiled in (the `xla` dependency is the vendored \
             stub; point it at the real crate); falling back to pure-Rust kernels",
        )
    }
}

impl std::error::Error for Unavailable {}

pub struct PjRtClient(Never);

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Unavailable> {
        Err(Unavailable)
    }

    pub fn platform_name(&self) -> String {
        match self.0 {}
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, Unavailable> {
        match self.0 {}
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto, Unavailable> {
        Err(Unavailable)
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable(Never);

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Unavailable> {
        match self.0 {}
    }
}

pub struct PjRtBuffer(Never);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Unavailable> {
        match self.0 {}
    }
}

pub struct Literal(Never);

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        unreachable!("xla stub: no Literal can exist without a client")
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Unavailable> {
        match self.0 {}
    }

    pub fn to_tuple1(self) -> Result<Literal, Unavailable> {
        match self.0 {}
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal), Unavailable> {
        match self.0 {}
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Unavailable> {
        match self.0 {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_startup_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must refuse to start");
        assert!(format!("{err}").contains("falling back"));
    }

    #[test]
    fn proto_load_fails_cleanly() {
        assert!(HloModuleProto::from_text_file(Path::new("/nope.hlo.txt")).is_err());
        // Computation construction is inert (no client involved).
        let _ = XlaComputation::from_proto(&HloModuleProto);
    }
}

//! Offline shim for the `log` facade crate.
//!
//! Reimplements the subset this repo uses — the five level macros, the
//! [`Log`] trait, [`set_logger`]/[`set_max_level`], and the
//! [`Level`]/[`LevelFilter`]/[`Metadata`]/[`Record`] types — so the
//! crate builds without registry access. Semantics follow the real
//! crate: `Error` is the most severe level, filtering is
//! `level <= max_level`, and `set_logger` is first-call-wins.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Log verbosity level. Ordering: `Error < Warn < Info < Debug < Trace`
/// (matching the real crate, where a *lower* level is more severe).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn to_level_filter(self) -> LevelFilter {
        match self {
            Level::Error => LevelFilter::Error,
            Level::Warn => LevelFilter::Warn,
            Level::Info => LevelFilter::Info,
            Level::Debug => LevelFilter::Debug,
            Level::Trace => LevelFilter::Trace,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

/// Level filter: like [`Level`] plus `Off`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl LevelFilter {
    fn from_usize(v: usize) -> LevelFilter {
        match v {
            0 => LevelFilter::Off,
            1 => LevelFilter::Error,
            2 => LevelFilter::Warn,
            3 => LevelFilter::Info,
            4 => LevelFilter::Debug,
            _ => LevelFilter::Trace,
        }
    }
}

/// Metadata about a log invocation (level + target module path).
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log invocation: metadata plus the formatted arguments.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool;
    fn log(&self, record: &Record<'_>);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0); // Off until set

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

/// Install the global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    LevelFilter::from_usize(MAX_LEVEL.load(Ordering::Relaxed))
}

/// Macro plumbing: filter, build the [`Record`], dispatch. Public so the
/// exported macros can reach it; not part of the stable shim surface.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level.to_level_filter() > max_level() {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    static HITS: AtomicU64 = AtomicU64::new(0);

    struct CountingLogger;

    impl Log for CountingLogger {
        fn enabled(&self, metadata: &Metadata<'_>) -> bool {
            metadata.level() <= Level::Info
        }

        fn log(&self, record: &Record<'_>) {
            if self.enabled(record.metadata()) {
                HITS.fetch_add(1, Ordering::SeqCst);
            }
        }

        fn flush(&self) {}
    }

    #[test]
    fn filtering_and_dispatch() {
        let _ = set_logger(&CountingLogger);
        set_max_level(LevelFilter::Info);
        let before = HITS.load(Ordering::SeqCst);
        info!("hello {}", 1);
        debug!("filtered out by max_level");
        assert_eq!(HITS.load(Ordering::SeqCst), before + 1);
        assert!(Level::Error < Level::Trace);
        assert_eq!(LevelFilter::Trace.min(LevelFilter::Warn), LevelFilter::Warn);
        assert_eq!(Level::Warn.to_level_filter(), LevelFilter::Warn);
        assert_eq!(format!("{:<5}", Level::Warn), "WARN ");
    }
}

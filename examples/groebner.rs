//! Gröbner bases with parallel Buchberger — the application domain the
//! paper's references [5, 6, 9] study (parallel polynomial operations in
//! the large Buchberger algorithm).
//!
//! ```bash
//! cargo run --release --example groebner
//! ```
//!
//! Computes reduced Gröbner bases for classic small ideals (Cox–Little–
//! O'Shea's textbook ideal, cyclic-3, Katsura-3) over exact rationals,
//! sequentially and with generation-parallel pair reduction, verifies
//! both against Buchberger's criterion, and reports timings.

use std::time::Instant;

use stream_future::exec::Executor;
use stream_future::poly::groebner::{buchberger_par, buchberger_seq, is_groebner};
use stream_future::poly::{parse_polynomial, Polynomial};
use stream_future::rational::Rational;

fn parse_all(inputs: &[&str], names: &[&str]) -> Vec<Polynomial<Rational>> {
    inputs.iter().map(|s| parse_polynomial(s, names).unwrap()).collect()
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
    let exec = Executor::new(cores);

    let systems: Vec<(&str, Vec<&str>, Vec<&str>)> = vec![
        (
            "CLO textbook (grlex)",
            vec!["x", "y"],
            vec!["x^3 - 2*x*y", "x^2*y - 2*y^2 + x"],
        ),
        (
            "cyclic-3",
            vec!["x", "y", "z"],
            vec!["x + y + z", "x*y + y*z + z*x", "x*y*z - 1"],
        ),
        (
            "katsura-3",
            vec!["x", "y", "z"],
            vec![
                "x + 2*y + 2*z - 1",
                "x^2 + 2*y^2 + 2*z^2 - x",
                "2*x*y + 2*y*z - y",
            ],
        ),
        (
            "intersecting quadrics",
            vec!["x", "y", "z"],
            vec!["x^2 + y + z - 1", "x + y^2 + z - 1", "x + y + z^2 - 1"],
        ),
    ];

    for (name, vars, gens) in systems {
        println!("== {name} ==");
        let generators = parse_all(&gens, &vars);
        for g in &generators {
            println!("  in:  {g}");
        }

        let t = Instant::now();
        let seq = buchberger_seq(&generators);
        let t_seq = t.elapsed();
        let t = Instant::now();
        let par = buchberger_par(&exec, &generators);
        let t_par = t.elapsed();

        assert!(is_groebner(&seq), "sequential basis fails Buchberger's criterion");
        assert!(is_groebner(&par), "parallel basis fails Buchberger's criterion");
        assert_eq!(seq, par, "parallel and sequential bases differ");

        for b in &seq {
            println!("  out: {b}");
        }
        println!(
            "  seq {:.2?}  par({cores}) {:.2?}  [{} basis elements, verified]\n",
            t_seq,
            t_par,
            seq.len()
        );
    }
    println!("groebner OK");
}

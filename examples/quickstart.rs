//! Quickstart: the paper's construct in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! A stream algorithm is written once against the monadic interface;
//! substituting the `Future` strategy for `Lazy` (one argument) makes it
//! pipeline-parallel — the paper's central move.

use stream_future::prelude::*;
use stream_future::poly::{parse_polynomial, stream_times, Polynomial};
use stream_future::sieve;

fn main() {
    // ── 1. Streams over the Lazy monad (Scala's Stream) ─────────────
    let naturals = Stream::range(LazyEval, 1, 1_000_000);
    let first_squares: Vec<u32> = naturals.map_elems(|x| x * x).take(5).to_vec();
    println!("lazy squares:   {first_squares:?}");
    // Only 5 cells were ever computed; the range is a million long.

    // ── 2. Substitute Future for Lazy: same code, now parallel ──────
    let exec = Executor::new(2); // the paper's par(2)
    let eval = FutureEval::new(exec.clone());
    let naturals = Stream::range(eval, 1, 50);
    let squares: Vec<u32> = naturals.map_elems(|x| x * x).take(5).to_vec();
    println!("future squares: {squares:?}");
    println!(
        "executor ran {} tasks on {} workers",
        exec.stats().tasks_executed,
        exec.parallelism()
    );

    // ── 3. The paper's §5 prime sieve, both ways ─────────────────────
    let seq_primes = sieve::primes(LazyEval, 100);
    let par_primes = sieve::primes(FutureEval::new(Executor::new(2)), 100);
    assert_eq!(seq_primes, par_primes);
    println!("primes < 100:   {seq_primes:?}");

    // ── 4. The paper's §6 polynomial multiplication ──────────────────
    let a: Polynomial<i64> = parse_polynomial("x^2 + 2*x*y + y^2", &["x", "y"]).unwrap();
    let b: Polynomial<i64> = parse_polynomial("x - y", &["x", "y"]).unwrap();
    let seq_prod = stream_times(&LazyEval, &a, &b);
    let par_prod = stream_times(&FutureEval::new(Executor::new(2)), &a, &b);
    assert_eq!(seq_prod, par_prod);
    assert_eq!(seq_prod, a.mul(&b)); // matches the classical algorithm
    println!("({a}) * ({b}) = {seq_prod}");

    println!("\nquickstart OK");
}

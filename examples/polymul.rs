//! The paper's §6 example: sparse polynomial multiplication on the
//! Fateman benchmark, comparing the three algorithms
//! (stream / parallel-collections list / chunked) and the two
//! coefficient rings (i64 vs BigInt×100000000001 — the paper's `_big`).
//!
//! ```bash
//! cargo run --release --example polymul -- [degree] [vars] [chunk]
//! ```

use std::sync::Arc;
use std::time::Instant;

use stream_future::bigint::BigInt;
use stream_future::poly::{
    chunked_times, list_times_par, list_times_seq, stream_times, Coeff, Polynomial,
    RustMultiplier,
};
use stream_future::prelude::*;
use stream_future::testkit::with_stack;
use stream_future::workload::{fateman_pair, fateman_pair_big, fateman_terms};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let degree: u32 = args.first().and_then(|s| s.parse().ok()).unwrap_or(6);
    let vars: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let chunk: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(64);

    println!(
        "Fateman benchmark: p = (1 + Σx)^{degree} over {vars} vars \
         ({} terms); computing p·(p+1)\n",
        fateman_terms(vars, degree)
    );

    println!("== small coefficients (i64) ==");
    let (p, q) = fateman_pair(vars, degree);
    run_all("i64", &p, &q, chunk);

    println!("\n== big coefficients (BigInt × 100000000001, the paper's `_big`) ==");
    let (pb, qb) = fateman_pair_big(vars, degree, 100_000_000_001);
    run_all("big", &pb, &qb, chunk);
}

fn run_all<C: Coeff>(tag: &str, p: &Polynomial<C>, q: &Polynomial<C>, chunk: usize) {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
    let want = time(&format!("[{tag}] classical mul (oracle)"), || p.mul(q));

    {
        let (p, q) = (p.clone(), q.clone());
        let got = time(&format!("[{tag}] stream seq"), move || {
            with_stack(1024, move || stream_times(&LazyEval, &p, &q))
        });
        assert_eq!(got, want);
    }
    {
        let (p, q) = (p.clone(), q.clone());
        let eval = FutureEval::new(Executor::new(cores));
        let got = time(&format!("[{tag}] stream par({cores})"), move || {
            with_stack(1024, move || stream_times(&eval, &p, &q))
        });
        assert_eq!(got, want);
    }
    let got = time(&format!("[{tag}] list seq"), || list_times_seq(p, q));
    assert_eq!(got, want);
    let exec = Executor::new(cores);
    let got = time(&format!("[{tag}] list par({cores})"), || list_times_par(&exec, p, q));
    assert_eq!(got, want);
    let got = time(&format!("[{tag}] chunked({chunk}) seq"), || {
        chunked_times(&LazyEval, p, q, chunk, Arc::new(RustMultiplier))
    });
    assert_eq!(got, want);
    let eval = FutureEval::new(Executor::new(cores));
    let got = time(&format!("[{tag}] chunked({chunk}) par({cores})"), || {
        chunked_times(&eval, p, q, chunk, Arc::new(RustMultiplier))
    });
    assert_eq!(got, want);
    println!(
        "  result: {} terms, leading coefficient {}",
        want.num_terms(),
        want.leading().map(|(_, c)| c.to_string()).unwrap_or_default()
    );
}

fn time<R>(name: &str, f: impl FnOnce() -> R) -> R {
    let t = Instant::now();
    let out = f();
    println!("  {name:<32} {:>8.3}s", t.elapsed().as_secs_f64());
    out
}

// Keep BigInt in the example's public face (the `_big` ring).
#[allow(dead_code)]
fn big(x: i64) -> BigInt {
    BigInt::from(x)
}

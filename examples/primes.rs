//! The paper's §5 example: the (deliberately naive) trial-division prime
//! sieve as a stream pipeline, timed under every evaluation strategy.
//!
//! ```bash
//! cargo run --release --example primes -- [n] [chunk_size]
//! ```
//!
//! Reproduces the paper's observation 1: the stream sieve does *not*
//! scale (elementary operations too fine-grained), while the chunked
//! variant (§7's proposed improvement) does.

use std::time::Instant;

use stream_future::prelude::*;
use stream_future::sieve;
use stream_future::testkit::with_stack;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: u32 = args.first().and_then(|s| s.parse().ok()).unwrap_or(5_000);
    let chunk: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(256);

    println!("sieving primes below {n} (chunk_size={chunk} for the chunked variant)\n");
    let oracle = sieve::eratosthenes(n);
    println!("oracle (Eratosthenes): {} primes, largest {:?}", oracle.len(), oracle.last());

    // The paper's stream sieve under each strategy.
    let mut rows: Vec<(String, f64, usize)> = Vec::new();
    let t = Instant::now();
    let got = with_stack(1024, move || sieve::primes(LazyEval, n));
    rows.push(("stream seq (Lazy)".into(), t.elapsed().as_secs_f64(), got.len()));
    assert_eq!(got, oracle);

    for workers in [1, 2, num_cores()] {
        let exec = Executor::new(workers);
        let eval = FutureEval::new(exec);
        let t = Instant::now();
        let got = with_stack(1024, move || sieve::primes(eval, n));
        rows.push((format!("stream par({workers})"), t.elapsed().as_secs_f64(), got.len()));
        assert_eq!(got, oracle);
    }

    // The chunked variant (§7 improvement; our extension).
    let t = Instant::now();
    let got = sieve::chunked_primes(LazyEval, n, chunk);
    rows.push(("chunked seq".into(), t.elapsed().as_secs_f64(), got.len()));
    assert_eq!(got, oracle);

    let exec = Executor::new(num_cores());
    let eval = FutureEval::new(exec);
    let t = Instant::now();
    let got = sieve::chunked_primes(eval, n, chunk);
    rows.push((format!("chunked par({})", num_cores()), t.elapsed().as_secs_f64(), got.len()));
    assert_eq!(got, oracle);

    println!("\n{:<22} {:>10} {:>8}", "configuration", "seconds", "primes");
    for (name, secs, count) in &rows {
        println!("{name:<22} {secs:>10.3} {count:>8}");
    }
    println!("\nall configurations verified against Eratosthenes");
}

fn num_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2)
}

//! End-to-end driver: the full three-layer system on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example pipeline_e2e
//! ```
//!
//! Exercises every layer in one run:
//!   L1/L2 — the AOT Pallas/JAX artifacts are loaded and executed via
//!           PJRT for the chunked block products;
//!   L3   — the coordinator routes Table-1-style jobs through the
//!          stream/future machinery, verifies each against the oracle,
//!          and reports timings, throughput, engine and executor
//!          metrics.
//!
//! The printed report is recorded in EXPERIMENTS.md §End-to-end.

use anyhow::Result;
use stream_future::bench_harness::{render_table, Cell, ReportTable};
use stream_future::config::{Config, Mode};
use stream_future::coordinator::{JobRequest, Pipeline};
use stream_future::workload::fateman_terms;

fn main() -> Result<()> {
    let scale: f64 = std::env::var("SFUT_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.6);
    let mut cfg = Config::default();
    cfg.scale = scale;
    cfg.samples = 1;
    cfg.warmup = 0;

    let pipeline = Pipeline::new(cfg.clone())?;
    match pipeline.engine() {
        Some(engine) => println!(
            "PJRT engine up: platform={}, poly artifacts {:?}, sieve artifacts {:?}",
            engine.platform(),
            engine.poly_shapes(),
            engine.sieve_shapes()
        ),
        None => println!(
            "WARNING: artifacts not built — chunked workloads fall back to rust-scalar \
             (run `make artifacts`)"
        ),
    }

    let degree = cfg.scaled_fateman_degree();
    let terms = fateman_terms(cfg.fateman_vars, degree);
    let term_products = terms * terms;
    println!(
        "workload: Fateman p·(p+1), (1+Σx)^{degree} over {} vars = {terms} terms \
         ({term_products} term-products); primes n={}\n",
        cfg.fateman_vars,
        cfg.scaled_primes_n()
    );

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
    let mut modes = vec![Mode::Seq, Mode::Par(1), Mode::Par(2)];
    if cores > 2 {
        modes.push(Mode::Par(cores));
    }
    let cols: Vec<String> = modes.iter().map(Mode::label).collect();
    let mut table = ReportTable::new(
        "End-to-end timings (seconds)",
        cols.iter().map(String::as_str).collect(),
    );

    // The registry's scenarios, paper originals and plugin extensions
    // alike — all through the same by-name request path.
    let workloads = [
        "primes", "stream", "stream_big", "list", "list_big", "chunked", "chunked_big", "fib",
        "msort",
    ];
    for w in workloads {
        for &m in &modes {
            let req = JobRequest::named(w, m);
            let result = pipeline.run(&req)?;
            anyhow::ensure!(result.verified, "{} failed verification", req.label());
            table.set(w, &m.label(), Cell::Seconds(result.seconds));
            if w == "chunked" && m == Mode::Seq {
                println!("chunked backend: {}", result.backend);
            }
        }
    }

    println!("\n{}", render_table(&table));

    // Throughput on the chunked kernel path.
    let fastest_par = format!("par({})", cores.min(2).max(1));
    if let Some(secs) = table.seconds("chunked", &fastest_par) {
        println!(
            "chunked {fastest_par} throughput: {:.1}M term-products/s",
            term_products as f64 / secs / 1e6
        );
    }

    if let Some(engine) = pipeline.engine() {
        let stats = engine.stats();
        println!(
            "\nengine stats: {} poly calls, {} sieve calls, {:.3}s total kernel exec",
            stats.poly_calls,
            stats.sieve_calls,
            stats.total_exec_nanos as f64 / 1e9
        );
    }
    println!("\nmetrics snapshot:\n{}", pipeline.metrics().snapshot().render());
    println!("pipeline_e2e OK — all jobs verified against oracles");
    Ok(())
}

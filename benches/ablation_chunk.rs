//! A1 — the §7 chunking hypothesis, tested: chunk-size sweep on the
//! chunked_big workload against the unchunked stream algorithm.
//! Run: `cargo bench --bench ablation_chunk`.

mod common;

fn main() -> anyhow::Result<()> {
    let cfg = common::bench_config();
    common::banner("ablation_chunk (A1)", &cfg);
    let sizes = [1, 4, 16, 64, 128, 256];
    let report = stream_future::bench_harness::paper::ablation_chunk(&cfg, &sizes)?;
    println!("{report}");
    Ok(())
}

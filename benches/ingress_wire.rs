//! Framed-vs-text ingress saturation A/B → `BENCH_ingress.json`.
//!
//! One invocation sweeps BOTH wire modes over a connection ladder
//! against otherwise identical pipelines — and, on the framed side,
//! the readiness backends (`poll`/`epoll`) crossed with a
//! reactor-count ladder (see `bench_harness::ingress_bench` for the
//! measurement discipline and the both-modes / both-pollers gate
//! invariants). Release numbers overwrite any test-seeded trajectory
//! file; the CI ingress gate (`ci/check_bench.sh ingress`) compares
//! the overwritten file against the committed baseline via
//! `sfut check-bench`.
//!
//! Environment knobs (on top of `benches/common`'s `SFUT_SCALE`,
//! `SFUT_BENCH_SAMPLES`, `SFUT_BENCH_WARMUP`, `SFUT_NO_KERNEL`):
//! * `SFUT_INGRESS_CONNS`    — connection ladder, e.g. `1,2,4`
//!   (default 1,2)
//! * `SFUT_INGRESS_JOBS`     — submit→wait round-trips per connection
//!   per sample (default 3)
//! * `SFUT_INGRESS_POLLERS`  — framed readiness backends, e.g.
//!   `poll,epoll` (default: both on linux, `poll` elsewhere)
//! * `SFUT_INGRESS_REACTORS` — framed reactor-count ladder, e.g.
//!   `1,2,4` (default 1,2)
//!
//! Run: `cargo bench --bench ingress_wire`.

mod common;

use stream_future::bench_harness::{ingress_bench, BenchOptions};

fn main() {
    let cfg = common::bench_config();
    common::banner("ingress_wire", &cfg);

    let params = ingress_bench::IngressBenchParams {
        connections: ingress_bench::connections_from_env().unwrap_or_else(|| vec![1, 2]),
        jobs_per_connection: ingress_bench::jobs_from_env().unwrap_or(3),
        pollers: ingress_bench::pollers_from_env()
            .unwrap_or_else(ingress_bench::default_pollers),
        reactor_counts: ingress_bench::reactor_counts_from_env().unwrap_or_else(|| vec![1, 2]),
        ..Default::default()
    };
    let opts = BenchOptions {
        warmup: cfg.warmup.max(1),
        samples: cfg.samples.max(3),
        verbose: false,
    };
    eprintln!(
        "wires={:?} pollers={:?} reactors={:?} connections={:?} jobs/connection={}",
        params.wires.iter().map(|w| w.label()).collect::<Vec<_>>(),
        params.pollers.iter().map(|p| p.label()).collect::<Vec<_>>(),
        params.reactor_counts,
        params.connections,
        params.jobs_per_connection
    );

    let bench = ingress_bench::run(&cfg, &params, &opts).expect("ingress bench failed");
    println!(
        "\ningress wire saturation ({} profile, {} jobs/connection):",
        bench.profile, bench.jobs_per_connection
    );
    for p in &bench.points {
        println!(
            "  {:<7} poller={:<5} reactors={:<2} conns={:<4} {:>10.1} jobs/s   \
             p50={:>8.2}ms p95={:>8.2}ms shed={:>5.1}%",
            p.wire,
            p.poller,
            p.reactors,
            p.connections,
            p.jobs_per_sec,
            p.p50_ms,
            p.p95_ms,
            p.shed_rate * 100.0
        );
    }

    let out = ingress_bench::default_output_path();
    match ingress_bench::write_json(&bench, &out) {
        Ok(()) => println!("\nwrote {}", out.display()),
        Err(e) => {
            // Exiting nonzero matters: if the trajectory file silently
            // kept its old contents, the CI gate would compare the
            // committed baseline against itself and always pass.
            eprintln!("\ncould not write {}: {e}", out.display());
            std::process::exit(1);
        }
    }
    println!("ingress_wire done");
}

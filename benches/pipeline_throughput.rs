//! Pipeline throughput across shard counts → `BENCH_pipeline.json`.
//!
//! Drives concurrent clients through the sharded coordinator at shards
//! ∈ {1, 2, N} for each trajectory workload, recording jobs/sec and
//! p50/p95 latency per cell (see `bench_harness::pipeline_bench` for
//! the measurement discipline). Release numbers overwrite any
//! test-seeded trajectory file; the JSON's `profile` field records
//! which build produced it, and the CI bench gate
//! (`ci/check_bench.sh`) only compares like-for-like runs.
//!
//! Environment knobs (on top of `benches/common`'s `SFUT_SCALE`,
//! `SFUT_BENCH_SAMPLES`, `SFUT_BENCH_WARMUP`, `SFUT_NO_KERNEL`):
//! * `SFUT_PIPELINE_CLIENTS` — concurrent client threads (default 4)
//! * `SFUT_PIPELINE_JOBS`    — jobs per client per sample (default 4)
//!
//! Run: `cargo bench --bench pipeline_throughput`.

mod common;

use stream_future::bench_harness::{pipeline_bench, BenchOptions};
use stream_future::config::Mode;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default).max(1)
}

fn main() {
    let cfg = common::bench_config();
    common::banner("pipeline_throughput", &cfg);

    let params = pipeline_bench::PipelineBenchParams {
        clients: env_usize("SFUT_PIPELINE_CLIENTS", 4),
        jobs_per_client: env_usize("SFUT_PIPELINE_JOBS", 4),
        shard_counts: pipeline_bench::default_shard_counts(cfg.shard_parallelism),
        mode: Mode::Par(2),
        // The whole registry: newly registered plugins grow trajectory
        // columns without touching this bench.
        workloads: pipeline_bench::trajectory_workloads(),
    };
    let opts = BenchOptions {
        warmup: cfg.warmup.max(1),
        samples: cfg.samples.max(3),
        verbose: false,
    };
    eprintln!(
        "clients={} jobs/client={} shard sweep={:?}",
        params.clients, params.jobs_per_client, params.shard_counts
    );

    let bench = pipeline_bench::run(&cfg, &params, &opts).expect("pipeline bench failed");
    println!(
        "\npipeline throughput ({} profile, {} clients × {} jobs):",
        bench.profile, bench.clients, bench.jobs_per_client
    );
    for p in &bench.points {
        println!(
            "  {:<16} shards={:<2} {:>10.1} jobs/s   p50={:>8.2}ms p95={:>8.2}ms \
             qwait p50={:>7.2}ms p95={:>7.2}ms shed={:>5.1}% steals={:<6} verified={}",
            p.workload,
            p.shards,
            p.jobs_per_sec,
            p.p50_ms,
            p.p95_ms,
            p.queue_wait_p50_ms,
            p.queue_wait_p95_ms,
            p.shed_rate * 100.0,
            p.tasks_stolen,
            p.verified
        );
    }

    let out = pipeline_bench::default_output_path();
    match pipeline_bench::write_json(&bench, &out) {
        Ok(()) => println!("\nwrote {}", out.display()),
        Err(e) => {
            // Exiting nonzero matters: if the trajectory file silently
            // kept its old contents, the CI gate would compare the
            // committed baseline against itself and always pass.
            eprintln!("\ncould not write {}: {e}", out.display());
            std::process::exit(1);
        }
    }
    println!("pipeline_throughput done");
}

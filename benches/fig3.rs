//! Regenerates the paper's fig3 (see bench_harness::paper::fig3).
//! Run: `cargo bench --bench fig3` (env knobs in benches/common/mod.rs).

mod common;

fn main() -> anyhow::Result<()> {
    let cfg = common::bench_config();
    common::banner("fig3", &cfg);
    let report = stream_future::bench_harness::paper::fig3(&cfg)?;
    println!("{report}");
    Ok(())
}

//! Regenerates the paper's table1 (see bench_harness::paper::table1).
//! Run: `cargo bench --bench table1` (env knobs in benches/common/mod.rs).

mod common;

fn main() -> anyhow::Result<()> {
    let cfg = common::bench_config();
    common::banner("table1", &cfg);
    let report = stream_future::bench_harness::paper::table1(&cfg)?;
    println!("{report}");
    Ok(())
}

//! A3 — overhead anatomy: microbenchmarks for the mechanisms behind the
//! paper's observations 1–4 (task grain vs coordination overhead).
//!
//! Measures, in order:
//! 1. executor task spawn→complete cost (the price of one stream cell
//!    under the Future strategy);
//! 2. suspension construction+force cost per strategy (Lazy vs Future vs
//!    Strict) over a stream walk;
//! 3. `Fut` continuation-chain cost per stage (`map` without forcing);
//! 4. the elementary-operation footprint knob: one term-product
//!    multiply-add at growing coefficient sizes (i64 → BigInt at
//!    100000000001^k), i.e. *why* `stream_big` recovers;
//! 5. executor queue throughput under producer contention;
//! 6. scheduler/deque A/B — the Mutex-queue baseline vs the
//!    work-stealing executor under both per-worker deque
//!    implementations (`deque=locked` and `deque=chase_lev`) on
//!    identical fan-out and spawn+force workloads, recorded as labeled
//!    datapoints to `BENCH_executor.json` for the perf trajectory
//!    (`sfut check-bench` compares like-labeled points only).
//!
//! Run: `cargo bench --bench ablation_overhead`.

mod common;

use std::time::Instant;

use stream_future::bench_harness::executor_bench;
use stream_future::bigint::BigInt;
use stream_future::exec::Executor;
use stream_future::poly::Coeff;
use stream_future::prelude::*;
use stream_future::susp::Fut;

fn time_per<R>(label: &str, iters: u64, f: impl FnOnce() -> R) -> f64 {
    let t = Instant::now();
    let _keep = f();
    let total = t.elapsed().as_secs_f64();
    let per = total / iters as f64 * 1e9;
    println!("{label:<52} {per:>12.1} ns/op   ({total:.3}s / {iters} ops)");
    per
}

fn main() {
    let cfg = common::bench_config();
    common::banner("ablation_overhead (A3)", &cfg);
    let n: u64 = (100_000f64 * cfg.scale) as u64;
    let n = n.max(10_000);

    // 1. Raw task spawn→complete.
    {
        let ex = Executor::new(1);
        time_per("task spawn+complete (par(1) pool)", n, || {
            for _ in 0..n {
                ex.spawn(|| {});
            }
            ex.wait_idle();
        });
    }

    // 2. Stream-cell cost per strategy.
    {
        let len = n as u32;
        time_per("stream cell construct+force, Lazy (seq)", n, || {
            Stream::range(LazyEval, 0, len).force_all()
        });
        time_per("stream cell construct+force, Strict", n, || {
            Stream::range(StrictEval, 0, len).force_all()
        });
        let ex = Executor::new(1);
        time_per("stream cell construct+force, Future par(1)", n, || {
            Stream::range(FutureEval::new(ex.clone()), 0, len).force_all()
        });
        let ex2 = Executor::new(2);
        time_per("stream cell construct+force, Future par(2)", n, || {
            Stream::range(FutureEval::new(ex2.clone()), 0, len).force_all()
        });
    }

    // 3. Continuation chaining (map) per stage.
    {
        let ex = Executor::new(1);
        let depth = (n / 10).max(1_000);
        time_per("Fut::and_then chain, per stage", depth, || {
            let mut cur = Fut::spawn(&ex, || 0u64);
            for _ in 0..depth {
                cur = cur.and_then(|x| x + 1);
            }
            *cur.force()
        });
    }

    // 4. Elementary-op footprint sweep (the paper's `_big` knob).
    {
        let reps = (n / 10).max(1_000);
        let a = 123_456i64;
        let b = 789_012i64;
        time_per("term multiply-add, i64", reps, || {
            let mut acc = 0i64;
            for _ in 0..reps {
                acc = acc.wrapping_add(std::hint::black_box(a).wrapping_mul(b));
            }
            acc
        });
        let factor = BigInt::from(100_000_000_001i64);
        let mut fa = BigInt::from(a);
        let mut fb = BigInt::from(b);
        for k in 1..=4u32 {
            fa = Coeff::mul(&fa, &factor);
            fb = Coeff::mul(&fb, &factor);
            let (fa2, fb2) = (fa.clone(), fb.clone());
            let label = format!(
                "term multiply-add, BigInt ~{} limbs (factor^{k})",
                fa.limb_len() + fb.limb_len()
            );
            time_per(&label, reps, move || {
                let mut acc = BigInt::zero();
                for _ in 0..reps {
                    acc = Coeff::add(&acc, &Coeff::mul(&fa2, &fb2));
                }
                acc
            });
        }
    }

    // 5. Queue throughput under contention.
    {
        for workers in [1usize, 2, 4] {
            let ex = Executor::new(workers);
            let label = format!("queue throughput, {workers} workers, 4 producers");
            time_per(&label, n, || {
                std::thread::scope(|s| {
                    for _ in 0..4 {
                        let ex = ex.clone();
                        let per = n / 4;
                        s.spawn(move || {
                            for _ in 0..per {
                                ex.spawn(|| {});
                            }
                        });
                    }
                });
                ex.wait_idle();
            });
        }
    }

    // 6. Scheduler/deque A/B: baseline global queue vs work-stealing
    //    under the locked and Chase–Lev deques, full size, written to
    //    BENCH_executor.json (release numbers overwrite any test-seeded
    //    file; the JSON's `profile` field records which build produced
    //    it, and each run carries its scheduler/deque label).
    {
        let par = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).min(8);
        let tasks = n.max(100_000);
        let opts = stream_future::bench_harness::BenchOptions {
            warmup: cfg.warmup.max(1),
            samples: cfg.samples.max(3),
            verbose: false,
        };
        let b = executor_bench::run(tasks, par, &opts);
        println!("\nscheduler/deque A/B ({tasks} tasks, par({par})):");
        for r in &b.runs {
            println!(
                "  {:<13} deque={:<9} spawn_wave {:>10.1} t/s ({:.2}x) | \
                 fut_force {:>10.1} t/s ({:.2}x) | stolen {} batched {} migrated {} \
                 | depth p99 {}",
                r.scheduler,
                r.deque,
                r.spawn_wave_tasks_per_sec,
                r.speedup_spawn_wave,
                r.fut_force_tasks_per_sec,
                r.speedup_fut_force,
                r.tasks_stolen,
                r.steals_batched,
                r.jobs_migrated,
                r.queue_depth.p99,
            );
        }
        let out = executor_bench::default_output_path();
        match executor_bench::write_json(&b, &out) {
            Ok(()) => println!("  wrote {}", out.display()),
            Err(e) => {
                // A failed write must fail the bench run: exiting 0
                // would leave a stale trajectory file that a later
                // check-bench compares as if it were this run.
                eprintln!("  could not write {}: {e}", out.display());
                std::process::exit(1);
            }
        }
    }

    println!("\nablation_overhead done");
}

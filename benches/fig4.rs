//! Regenerates the paper's fig4 (see bench_harness::paper::fig4).
//! Run: `cargo bench --bench fig4` (env knobs in benches/common/mod.rs).

mod common;

fn main() -> anyhow::Result<()> {
    let cfg = common::bench_config();
    common::banner("fig4", &cfg);
    let report = stream_future::bench_harness::paper::fig4(&cfg)?;
    println!("{report}");
    Ok(())
}

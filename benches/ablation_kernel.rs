//! A2 — chunked-multiply backend ablation: AOT PJRT kernel vs the
//! pure-Rust scalar block multiplier.
//! Run: `cargo bench --bench ablation_kernel`.

mod common;

fn main() -> anyhow::Result<()> {
    let cfg = common::bench_config();
    common::banner("ablation_kernel (A2)", &cfg);
    let report = stream_future::bench_harness::paper::ablation_kernel(&cfg)?;
    println!("{report}");
    Ok(())
}

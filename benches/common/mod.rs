//! Shared bench-target plumbing.
//!
//! Every `cargo bench` target regenerates one of the paper's evaluation
//! artifacts via the library's `bench_harness::paper` module, so the CLI
//! (`sfut table1`) and `cargo bench --bench table1` print identical
//! reports.
//!
//! Environment knobs:
//! * `SFUT_SCALE`          — workload scale (default 0.35 so a full
//!   `cargo bench` sweep finishes in minutes; 1.0 = paper size —
//!   EXPERIMENTS.md records the scale=1.0 runs)
//! * `SFUT_BENCH_SAMPLES`  — samples per cell (default 1)
//! * `SFUT_BENCH_WARMUP`   — warmup runs per cell (default 1; the warmup
//!   also absorbs allocator settling between RSS-heavy cells)
//! * `SFUT_NO_KERNEL=1`    — disable the PJRT engine

use stream_future::config::Config;

pub fn bench_config() -> Config {
    let mut cfg = Config::default();
    cfg.scale = std::env::var("SFUT_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.35);
    cfg.samples = std::env::var("SFUT_BENCH_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    cfg.warmup = std::env::var("SFUT_BENCH_WARMUP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    if std::env::var("SFUT_NO_KERNEL").is_ok() {
        cfg.use_kernel = false;
    }
    // `cargo bench` runs from the workspace root; resolve artifacts
    // relative to the manifest so the engine finds them from anywhere.
    cfg.artifacts_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg
}

pub fn banner(name: &str, cfg: &Config) {
    eprintln!(
        "== {name} :: scale={} samples={} warmup={} kernel={} ==",
        cfg.scale, cfg.samples, cfg.warmup, cfg.use_kernel
    );
}

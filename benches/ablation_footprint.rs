//! A4 — elementary-operation footprint sweep on the *stream* algorithm:
//! the mechanism behind the paper's F3 ("the overhead incurred by
//! parallelization … is compensated when the footprint of coefficients
//! is big enough").
//!
//! The paper turns the knob once (×100000000001). On a JVM that single
//! step makes each multiply-add micro-second-scale; our BigInt does the
//! same product in ~40 ns, so one step is invisible against ~1.2 µs of
//! Future machinery. This sweep raises the factor to the k-th power
//! (coefficients of ~2k limbs) and reports par(1)/seq — the overhead
//! ratio must fall monotonically toward 1 as the footprint grows, which
//! is exactly F3's mechanism expressed on a 1-core testbed.
//!
//! Run: `cargo bench --bench ablation_footprint`.

mod common;

use std::time::Instant;

use stream_future::bigint::BigInt;
use stream_future::poly::{stream_times, Polynomial};
use stream_future::prelude::*;
use stream_future::testkit::with_stack;
use stream_future::workload::fateman_pair;

fn main() {
    let cfg = common::bench_config();
    common::banner("ablation_footprint (A4)", &cfg);
    // Smaller degree than Table 1: BigInt^16 coefficients are heavy.
    let degree = (cfg.scaled_fateman_degree() / 2).max(3);
    let (p_small, q_small) = fateman_pair(cfg.fateman_vars, degree);
    println!(
        "workload: Fateman (1+Σx)^{degree} over {} vars, coefficients × {}^k\n",
        cfg.fateman_vars, cfg.big_factor
    );
    println!(
        "{:>4} {:>7} {:>10} {:>10} {:>12}",
        "k", "limbs", "seq (s)", "par(1) (s)", "par(1)/seq"
    );

    let factor = BigInt::from(cfg.big_factor);
    for k in [0u32, 1, 2, 4, 8, 16, 32] {
        let mut scale = BigInt::one();
        for _ in 0..k {
            scale = &scale * &factor;
        }
        let p: Polynomial<BigInt> =
            p_small.map_coeffs(|c| &BigInt::from(*c) * &scale);
        let q: Polynomial<BigInt> =
            q_small.map_coeffs(|c| &BigInt::from(*c) * &scale);
        let limbs = p.leading().map(|(_, c)| c.limb_len()).unwrap_or(0);

        let want = p.mul(&q);

        let (ps, qs) = (p.clone(), q.clone());
        let t = Instant::now();
        let got = with_stack(1024, move || stream_times(&LazyEval, &ps, &qs));
        let seq = t.elapsed().as_secs_f64();
        assert_eq!(got, want, "seq k={k}");

        let (pp, qp) = (p.clone(), q.clone());
        let eval = FutureEval::new(Executor::new(1));
        let t = Instant::now();
        let got = with_stack(1024, move || stream_times(&eval, &pp, &qp));
        let par1 = t.elapsed().as_secs_f64();
        assert_eq!(got, want, "par1 k={k}");

        println!("{k:>4} {limbs:>7} {seq:>10.3} {par1:>10.3} {:>12.2}", par1 / seq);
    }
    println!("\nablation_footprint done (ratio must fall toward 1 as k grows — F3's mechanism)");
}

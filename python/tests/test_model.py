"""L2 model entry points: shapes, dtypes, and AOT signatures."""

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from compile import model


def test_poly_block_outer_shapes_and_dtypes():
    bx, by, v = 32, 32, 8
    xe = jnp.zeros((bx, v), jnp.int32)
    xc = jnp.ones((bx,), jnp.float64)
    ye = jnp.zeros((by, v), jnp.int32)
    yc = jnp.ones((by,), jnp.float64)
    oe, oc = model.poly_block_outer(xe, xc, ye, yc)
    assert oe.shape == (bx * by, v) and oe.dtype == jnp.int32
    assert oc.shape == (bx * by,) and oc.dtype == jnp.float64
    assert np.all(np.asarray(oc) == 1.0)


def test_sieve_block_mask_shapes():
    cands = jnp.arange(2, 2 + 512, dtype=jnp.int32)
    primes = jnp.full((64,), 2**31 - 1, jnp.int32)
    mask = model.sieve_block_mask(cands, primes)
    assert mask.shape == (512,) and mask.dtype == jnp.int32
    assert np.all(np.asarray(mask) == 1)  # sentinel-only primes eliminate nothing


def test_example_args_match_entry_points():
    args = model.example_args_poly(32, 32, 8)
    lowered = jax.jit(model.poly_block_outer).lower(*args)
    assert lowered is not None
    args = model.example_args_sieve(512, 64)
    lowered = jax.jit(model.sieve_block_mask).lower(*args)
    assert lowered is not None

"""AOT pipeline: HLO text artifacts are well-formed and shape-correct."""

import os
import subprocess
import sys

import pytest

from compile import aot, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_produces_hlo_module():
    text = aot.to_hlo_text(model.poly_block_outer, model.example_args_poly(32, 32, 8))
    assert text.startswith("HloModule")
    # return_tuple=True: the ROOT computation yields a tuple.
    assert "ROOT" in text
    assert "tuple" in text


def test_hlo_text_mentions_shapes():
    text = aot.to_hlo_text(model.sieve_block_mask, model.example_args_sieve(512, 64))
    assert "s32[512]" in text
    assert "s32[64]" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.toml")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_lists_every_artifact():
    with open(os.path.join(ARTIFACTS, "manifest.toml")) as f:
        manifest = f.read()
    for bx, by in aot.POLY_VARIANTS:
        name = f"poly_outer_{bx}x{by}"
        assert f"[{name}]" in manifest
        assert os.path.exists(os.path.join(ARTIFACTS, f"{name}.hlo.txt"))
    for b, p in aot.SIEVE_VARIANTS:
        name = f"sieve_mask_{b}x{p}"
        assert f"[{name}]" in manifest
        assert os.path.exists(os.path.join(ARTIFACTS, f"{name}.hlo.txt"))


def test_aot_main_is_idempotent(tmp_path):
    # Small smoke: running the module twice produces identical artifacts.
    out = tmp_path / "arts"
    cmd = [sys.executable, "-m", "compile.aot", "--out-dir", str(out)]
    cwd = os.path.join(os.path.dirname(__file__), "..")
    subprocess.run(cmd, cwd=cwd, check=True, capture_output=True)
    first = {p.name: p.read_text() for p in out.iterdir()}
    subprocess.run(cmd, cwd=cwd, check=True, capture_output=True)
    second = {p.name: p.read_text() for p in out.iterdir()}
    assert first == second

"""Kernel vs pure-jnp reference — the core L1 correctness signal."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from compile.kernels.outer import TILE_X, block_outer, vmem_footprint_bytes
from compile.kernels.ref import block_outer_ref, sieve_mask_ref
from compile.kernels.sievemask import TILE_C, sieve_mask

SENTINEL = 2**31 - 1


def random_term_block(rng, count, nvars, coef_scale=1000.0):
    exps = rng.integers(0, 30, size=(count, nvars)).astype(np.int32)
    coefs = rng.integers(-coef_scale, coef_scale + 1, size=(count,)).astype(np.float64)
    return jnp.asarray(exps), jnp.asarray(coefs)


class TestBlockOuter:
    @pytest.mark.parametrize("bx,by,v", [(8, 8, 4), (32, 32, 8), (8, 16, 8), (64, 64, 8)])
    def test_matches_ref(self, bx, by, v):
        rng = np.random.default_rng(42 + bx + by + v)
        xe, xc = random_term_block(rng, bx, v)
        ye, yc = random_term_block(rng, by, v)
        ke, kc = block_outer(xe, xc, ye, yc)
        re, rc = block_outer_ref(xe, xc, ye, yc)
        np.testing.assert_array_equal(np.asarray(ke), np.asarray(re))
        np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))

    def test_row_major_layout(self):
        # out[i*By + j] = x[i] * y[j] — the Rust unpack relies on it.
        xe = jnp.zeros((8, 2), jnp.int32).at[1, 0].set(5)
        xc = jnp.arange(1.0, 9.0)
        ye = jnp.zeros((8, 2), jnp.int32).at[2, 1].set(7)
        yc = jnp.arange(10.0, 18.0)
        ke, kc = block_outer(xe, xc, ye, yc)
        assert kc[1 * 8 + 2] == xc[1] * yc[2]
        np.testing.assert_array_equal(np.asarray(ke[1 * 8 + 2]), [5, 7])

    def test_zero_coefficients_pass_through(self):
        # Zero-padding of ragged blocks must produce zero products.
        xe = jnp.ones((8, 4), jnp.int32)
        xc = jnp.zeros((8,))
        ye = jnp.ones((8, 4), jnp.int32)
        yc = jnp.ones((8,))
        _, kc = block_outer(xe, xc, ye, yc)
        assert np.all(np.asarray(kc) == 0.0)

    def test_exactness_at_2_53_boundary(self):
        big = float(2**26)
        xe = jnp.zeros((8, 2), jnp.int32)
        xc = jnp.full((8,), big)
        ye = jnp.zeros((8, 2), jnp.int32)
        yc = jnp.full((8,), big)
        _, kc = block_outer(xe, xc, ye, yc)
        assert np.all(np.asarray(kc) == 2.0**52)

    def test_rejects_non_tile_multiple(self):
        xe = jnp.zeros((TILE_X + 1, 2), jnp.int32)
        xc = jnp.zeros((TILE_X + 1,))
        ye = jnp.zeros((8, 2), jnp.int32)
        yc = jnp.zeros((8,))
        with pytest.raises(ValueError, match="multiple of TILE_X"):
            block_outer(xe, xc, ye, yc)

    @settings(max_examples=25, deadline=None)
    @given(
        bx_tiles=st.integers(1, 4),
        by=st.integers(1, 48),
        v=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, bx_tiles, by, v, seed):
        rng = np.random.default_rng(seed)
        xe, xc = random_term_block(rng, TILE_X * bx_tiles, v)
        ye, yc = random_term_block(rng, by, v)
        ke, kc = block_outer(xe, xc, ye, yc)
        re, rc = block_outer_ref(xe, xc, ye, yc)
        np.testing.assert_array_equal(np.asarray(ke), np.asarray(re))
        np.testing.assert_allclose(np.asarray(kc), np.asarray(rc), rtol=0, atol=0)

    def test_vmem_footprint_model(self):
        # One 128x128 f64 step stays far under 16 MB VMEM.
        assert vmem_footprint_bytes(128, 128, 8) < 16 * 2**20


class TestSieveMask:
    def pad_primes(self, primes, width=64):
        out = np.full((width,), SENTINEL, np.int32)
        out[: len(primes)] = primes
        return jnp.asarray(out)

    def test_matches_ref(self):
        cands = jnp.arange(2, 2 + TILE_C, dtype=jnp.int32)
        primes = self.pad_primes([2, 3, 5, 7, 11])
        got = sieve_mask(cands, primes)
        want = sieve_mask_ref(cands, primes)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_known_survivors(self):
        base = 100
        cands = jnp.arange(base, base + TILE_C, dtype=jnp.int32)
        primes = self.pad_primes([2, 3, 5, 7])
        got = np.asarray(sieve_mask(cands, primes))
        for i, c in enumerate(range(base, base + TILE_C)):
            want = all(c % p for p in (2, 3, 5, 7))
            assert got[i] == int(want), f"candidate {c}"

    def test_sentinel_padding_is_neutral(self):
        cands = jnp.arange(2, 2 + TILE_C, dtype=jnp.int32)
        p_narrow = self.pad_primes([2, 3], width=8)
        p_wide = self.pad_primes([2, 3], width=64)
        np.testing.assert_array_equal(
            np.asarray(sieve_mask(cands, p_narrow)),
            np.asarray(sieve_mask(cands, p_wide)),
        )

    def test_multi_tile_grid(self):
        cands = jnp.arange(2, 2 + 4 * TILE_C, dtype=jnp.int32)
        primes = self.pad_primes([2, 3, 5, 7, 11, 13])
        got = sieve_mask(cands, primes)
        want = sieve_mask_ref(cands, primes)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_rejects_non_tile_multiple(self):
        with pytest.raises(ValueError, match="multiple of TILE_C"):
            sieve_mask(jnp.zeros((5,), jnp.int32), jnp.ones((4,), jnp.int32))

    @settings(max_examples=25, deadline=None)
    @given(
        tiles=st.integers(1, 3),
        nprimes=st.integers(1, 20),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, tiles, nprimes, seed):
        rng = np.random.default_rng(seed)
        cands = jnp.asarray(
            rng.integers(2, 100_000, size=(tiles * TILE_C,)).astype(np.int32)
        )
        primes = self.pad_primes(
            sorted(set(rng.integers(2, 300, size=(nprimes,)).tolist()))
        )
        got = sieve_mask(cands, primes)
        want = sieve_mask_ref(cands, primes)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

"""Roofline / footprint model checks for DESIGN.md's TPU estimates."""

from compile.kernels.outer import TILE_X, vmem_footprint_bytes


def test_footprint_scales_linearly_in_by():
    f1 = vmem_footprint_bytes(128, 64, 8)
    f2 = vmem_footprint_bytes(128, 128, 8)
    assert f2 > f1
    # Output tile dominates: ~2x when By doubles.
    assert 1.5 < f2 / f1 < 2.5


def test_all_compiled_variants_fit_vmem():
    # Every AOT variant must keep one grid step far below 16 MB VMEM.
    from compile.aot import NVARS, POLY_VARIANTS

    for bx, by in POLY_VARIANTS:
        fp = vmem_footprint_bytes(bx, by, NVARS)
        assert fp < 16 * 2**20 / 4, f"{bx}x{by}: {fp} bytes"


def test_tile_x_is_sublane_aligned():
    assert TILE_X % 8 == 0

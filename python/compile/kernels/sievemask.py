"""L1 Pallas kernel: block trial-division survivor mask.

The chunked sieve (§7 improvement applied to the primes workload) tests a
block of candidates against the seed primes in one dense step: a
`candidates × primes` remainder grid reduced by logical-and over the
prime axis. The candidate axis is tiled with BlockSpec; the prime vector
is small (≤ P_PAD) and stays resident.

Padding contract: the prime vector is padded to a fixed width with a
sentinel **larger than every candidate** (the Rust side uses 2^31 - 1),
so `candidate % sentinel == candidate != 0` never eliminates anything.

`interpret=True`: see outer.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Candidate rows per grid step.
TILE_C = 128


def _sieve_kernel(cand_ref, prime_ref, mask_ref):
    """One grid step: TILE_C candidates against the whole prime vector.

    Refs (VMEM tiles):
      cand_ref:  i32[TILE_C]
      prime_ref: i32[P]
      mask_ref:  i32[TILE_C]
    """
    cand = cand_ref[...]
    primes = prime_ref[...]
    rem = cand[:, None] % primes[None, :]
    mask_ref[...] = jnp.all(rem != 0, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sieve_mask(candidates, primes, *, interpret=True):
    """i32[B] mask: 1 where the candidate survives all trial divisions.

    Shapes: candidates i32[B] with B divisible by TILE_C, primes i32[P].
    """
    (b,) = candidates.shape
    (p,) = primes.shape
    if b % TILE_C != 0:
        raise ValueError(f"B={b} must be a multiple of TILE_C={TILE_C}")
    grid = (b // TILE_C,)
    return pl.pallas_call(
        _sieve_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_C,), lambda i: (i,)),  # candidate tile
            pl.BlockSpec((p,), lambda i: (0,)),        # whole prime vector
        ],
        out_specs=pl.BlockSpec((TILE_C,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=interpret,
    )(candidates, primes)

"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Both references mirror the Rust scalar implementations
(`poly::RustMultiplier`, `sieve::RustSiever`) exactly; pytest checks
kernel == ref, and the Rust integration tests check PJRT(artifact) ==
Rust scalar, closing the loop.
"""

import jax.numpy as jnp


def block_outer_ref(x_exps, x_coefs, y_exps, y_coefs):
    """All pairwise term products of two term blocks.

    Args:
      x_exps:  i32[Bx, V] exponent rows.
      x_coefs: f64[Bx] coefficients.
      y_exps:  i32[By, V].
      y_coefs: f64[By].

    Returns:
      (i32[Bx*By, V] exponent sums, f64[Bx*By] coefficient products),
      row-major: out[i*By + j] = x[i] * y[j].
    """
    bx, v = x_exps.shape
    by, _ = y_exps.shape
    exps = (x_exps[:, None, :] + y_exps[None, :, :]).reshape(bx * by, v)
    coefs = (x_coefs[:, None] * y_coefs[None, :]).reshape(bx * by)
    return exps, coefs


def sieve_mask_ref(candidates, primes):
    """Survivor mask for block trial division.

    Args:
      candidates: i32[B] values to test (> 0).
      primes:     i32[P] trial divisors (> 0; pad with a sentinel larger
                  than every candidate, e.g. 2^31 - 1, so padding never
                  eliminates).

    Returns:
      i32[B]: 1 where the candidate is divisible by no prime, else 0.
    """
    rem = candidates[:, None] % primes[None, :]
    survives = jnp.all(rem != 0, axis=1)
    return survives.astype(jnp.int32)

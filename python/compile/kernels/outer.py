"""L1 Pallas kernel: dense per-chunk term outer product.

The paper's §7 observes that its stream pipeline only pays off once
"elementary computations" are big enough; the chunked extension makes the
elementary unit a *block pair* of polynomial terms, whose product is a
dense computation: an exponent broadcast-add plus a coefficient outer
product.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the coefficient outer
product is a rank-1 matmul `xc[:, None] @ yc[None, :]`, which maps onto
the MXU systolic array; the exponent add is pure VPU elementwise work.
BlockSpec tiles the x-side so one (TX × By) output tile plus its inputs
stay VMEM-resident; the grid walks x-tiles, which is the HBM↔VMEM
schedule the Scala original expressed with task granularity.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the interpreter to plain
HLO (same numerics, runnable from the Rust runtime). Real-TPU estimates
live in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# x-side tile rows per grid step. 8 keeps the output tile (8*By rows)
# aligned with the f32/f64 sublane quantum on real TPUs.
TILE_X = 8


def _outer_kernel(xe_ref, xc_ref, ye_ref, yc_ref, oe_ref, oc_ref):
    """One grid step: products of TILE_X x-terms against the whole y block.

    Refs (VMEM tiles):
      xe_ref: i32[TILE_X, V]   xc_ref: f64[TILE_X]
      ye_ref: i32[By, V]       yc_ref: f64[By]
      oe_ref: i32[TILE_X*By, V]
      oc_ref: f64[TILE_X*By]
    """
    xe = xe_ref[...]
    ye = ye_ref[...]
    tx, v = xe.shape
    by = ye.shape[0]
    # Exponent broadcast-add (VPU).
    oe_ref[...] = (xe[:, None, :] + ye[None, :, :]).reshape(tx * by, v)
    # Coefficient outer product as a rank-1 matmul (MXU on real TPU).
    xc = xc_ref[...].reshape(tx, 1)
    yc = yc_ref[...].reshape(1, by)
    oc_ref[...] = jnp.dot(xc, yc, preferred_element_type=jnp.float64).reshape(tx * by)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_outer(x_exps, x_coefs, y_exps, y_coefs, *, interpret=True):
    """All pairwise term products; out[i*By + j] = x[i] * y[j].

    Shapes: x_exps i32[Bx, V], x_coefs f64[Bx], y_exps i32[By, V],
    y_coefs f64[By] with Bx divisible by TILE_X.
    """
    bx, v = x_exps.shape
    by, _ = y_exps.shape
    if bx % TILE_X != 0:
        raise ValueError(f"Bx={bx} must be a multiple of TILE_X={TILE_X}")
    grid = (bx // TILE_X,)
    return pl.pallas_call(
        _outer_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_X, v), lambda i: (i, 0)),      # x exps tile
            pl.BlockSpec((TILE_X,), lambda i: (i,)),           # x coefs tile
            pl.BlockSpec((by, v), lambda i: (0, 0)),           # whole y exps
            pl.BlockSpec((by,), lambda i: (0,)),               # whole y coefs
        ],
        out_specs=[
            pl.BlockSpec((TILE_X * by, v), lambda i: (i, 0)),  # output exps tile
            pl.BlockSpec((TILE_X * by,), lambda i: (i,)),      # output coefs tile
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bx * by, v), jnp.int32),
            jax.ShapeDtypeStruct((bx * by,), jnp.float64),
        ],
        interpret=interpret,
    )(x_exps, x_coefs, y_exps, y_coefs)


def vmem_footprint_bytes(bx, by, v, tile_x=TILE_X):
    """Estimated VMEM residency of one grid step (DESIGN.md roofline)."""
    in_bytes = tile_x * v * 4 + tile_x * 8 + by * v * 4 + by * 8
    out_bytes = tile_x * by * v * 4 + tile_x * by * 8
    return in_bytes + out_bytes

"""L2: the JAX compute graphs the Rust hot path calls through PJRT.

The paper's contribution is coordination (L3); the dense per-chunk
computations of the §7 chunking extension live here. Each entry point is
a thin jitted wrapper over an L1 Pallas kernel plus any surrounding
glue, so the kernel lowers into the same HLO module and the whole thing
ships as one artifact.

f64 note: coefficients ride in f64 lanes; products are exact while they
stay within ±2^53, and the Rust side checks that per block pair before
offloading (poly::TermBlock::kernel_exact_with).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels.outer import block_outer  # noqa: E402
from .kernels.sievemask import sieve_mask  # noqa: E402


def poly_block_outer(x_exps, x_coefs, y_exps, y_coefs):
    """Chunked polynomial multiply, per-block-pair dense core.

    out[i*By + j] = (x_exps[i] + y_exps[j], x_coefs[i] * y_coefs[j]).
    Blocks shorter than the artifact shape are zero-padded by the caller
    (zero coefficients multiply to zero and are dropped on unpack).
    """
    return block_outer(x_exps, x_coefs, y_exps, y_coefs, interpret=True)


def sieve_block_mask(candidates, primes):
    """Chunked sieve survivor mask (see kernels/sievemask.py)."""
    return sieve_mask(candidates, primes, interpret=True)


def example_args_poly(bx, by, v):
    """Abstract input signature for AOT lowering of poly_block_outer."""
    return (
        jax.ShapeDtypeStruct((bx, v), jnp.int32),
        jax.ShapeDtypeStruct((bx,), jnp.float64),
        jax.ShapeDtypeStruct((by, v), jnp.int32),
        jax.ShapeDtypeStruct((by,), jnp.float64),
    )


def example_args_sieve(b, p):
    """Abstract input signature for AOT lowering of sieve_block_mask."""
    return (
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((p,), jnp.int32),
    )
